//! The intrusive-LRU rewrite of the plan cache must be behaviorally
//! indistinguishable from the original O(entries) min-tick scan: this
//! file drives random op sequences (inserts + re-touches across two of
//! the cache's maps) against both the real `PlanCache` and a shadow
//! reference model implementing the old scan-based eviction, asserting
//! after every op that
//!
//! * resident-byte accounting is byte-identical,
//! * the eviction count matches,
//! * exactly the same keys are resident (i.e. the eviction *order* is
//!   identical — any divergence in order shows up as a membership
//!   mismatch on the very next overflow).

use std::collections::HashMap;

use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::{Atomicity, DpPlan, DpStrategy};
use canzona::schedule::microgroup::{build_micro_groups, TpTask};
use canzona::sweep::{DpKey, PlanCache, TpKey};
use canzona::util::rng::Rng;

fn dp_key(stage: usize) -> DpKey {
    DpKey {
        model: Qwen3Size::S1_7B,
        stage,
        pp: 1,
        dp: 8,
        tp: 2,
        strategy: DpStrategy::LbAsc,
        optim: None,
        metric: CostMetric::Numel,
        alpha_bits: 1.0f64.to_bits(),
        bucket_elems: 40_000_000,
    }
}

fn tp_key(rank: usize) -> TpKey {
    TpKey {
        dp_key: dp_key(0),
        rank,
        c_max_bits: Some(512e6f64.to_bits()),
        optim: OptimKind::Muon,
    }
}

/// Deterministic synthetic DP plan whose heap size varies with `i`.
fn dp_plan(i: usize) -> DpPlan {
    let ranks = 2 + i % 5;
    DpPlan {
        ranks,
        cuts: vec![(0..=ranks).map(|r| r * (7 + i)).collect()],
        atomicity: Atomicity::None,
    }
}

/// Deterministic synthetic TP plan whose heap size varies with `i`.
fn tp_plan(i: usize) -> canzona::schedule::microgroup::TpPlan {
    let tasks: Vec<TpTask> = (0..(2 + i % 4))
        .map(|id| TpTask {
            id,
            name: format!("t{id}"),
            cost: 1.0 + id as f64,
            comm_bytes: 2.0,
            flops: 10.0,
            state_bytes: 4.0,
        })
        .collect();
    build_micro_groups(tasks, 2, 1e9)
}

/// One op against either map.
#[derive(Clone, Copy, Debug)]
enum Op {
    Dp(usize),
    Tp(usize),
}

/// The reference model: the pre-rewrite scan-based LRU. Entries carry a
/// monotonically increasing tick, bumped on every touch; eviction scans
/// for the minimum tick across both maps.
struct ShadowLru {
    budget: usize,
    tick: u64,
    bytes: usize,
    evictions: u64,
    dp: HashMap<usize, (usize, u64)>, // key index -> (bytes, tick)
    tp: HashMap<usize, (usize, u64)>,
}

impl ShadowLru {
    fn new(budget: usize) -> ShadowLru {
        ShadowLru { budget, tick: 0, bytes: 0, evictions: 0,
                    dp: HashMap::new(), tp: HashMap::new() }
    }

    fn touch_or_insert(&mut self, op: Op, weight: usize) {
        self.tick += 1;
        let t = self.tick;
        let slot = match op {
            Op::Dp(i) => self.dp.get_mut(&i),
            Op::Tp(i) => self.tp.get_mut(&i),
        };
        if let Some(e) = slot {
            e.1 = t;
            return;
        }
        if self.budget != 0 && weight > self.budget {
            return; // oversize: bypass, uncached
        }
        match op {
            Op::Dp(i) => self.dp.insert(i, (weight, t)),
            Op::Tp(i) => self.tp.insert(i, (weight, t)),
        };
        self.bytes += weight;
        while self.budget != 0 && self.bytes > self.budget {
            // The old implementation: scan every entry for the min tick.
            let dp_min = self.dp.iter().map(|(k, v)| (v.1, *k)).min();
            let tp_min = self.tp.iter().map(|(k, v)| (v.1, *k)).min();
            let freed = match (dp_min, tp_min) {
                (Some((td, kd)), Some((tt, kt))) => {
                    if td < tt {
                        self.dp.remove(&kd).unwrap().0
                    } else {
                        self.tp.remove(&kt).unwrap().0
                    }
                }
                (Some((_, kd)), None) => self.dp.remove(&kd).unwrap().0,
                (None, Some((_, kt))) => self.tp.remove(&kt).unwrap().0,
                (None, None) => break,
            };
            self.bytes -= freed;
            self.evictions += 1;
        }
    }
}

/// Probe the real cache's per-entry weight for each synthetic plan by
/// inserting it alone into a fresh unbounded cache.
fn probe_weights(n: usize) -> (Vec<usize>, Vec<usize>) {
    let mut dp_w = Vec::with_capacity(n);
    let mut tp_w = Vec::with_capacity(n);
    for i in 0..n {
        let c = PlanCache::unbounded();
        c.dp_plan(&dp_key(i), || dp_plan(i));
        dp_w.push(c.stats().resident_bytes as usize);
        let c = PlanCache::unbounded();
        c.tp_plan(&tp_key(i), || tp_plan(i));
        tp_w.push(c.stats().resident_bytes as usize);
    }
    (dp_w, tp_w)
}

#[test]
fn randomized_lru_matches_scan_reference() {
    const N_KEYS: usize = 10;
    let (dp_w, tp_w) = probe_weights(N_KEYS);
    let typical = dp_w.iter().chain(&tp_w).sum::<usize>() / (2 * N_KEYS);

    for seed in 0..12u64 {
        let mut rng = Rng::new(0xB10C ^ seed.wrapping_mul(0x9E3779B97F4A7C15));
        // Budgets from "fits ~1 entry" to "fits most", so eviction is
        // exercised at every pressure level.
        let budget = typical + rng.index(6 * typical).max(1);
        let cache = PlanCache::with_budget(budget);
        let mut shadow = ShadowLru::new(budget);

        for step in 0..300 {
            let i = rng.index(N_KEYS);
            let op = if rng.index(2) == 0 { Op::Dp(i) } else { Op::Tp(i) };
            match op {
                Op::Dp(i) => {
                    cache.dp_plan(&dp_key(i), || dp_plan(i));
                    shadow.touch_or_insert(op, dp_w[i]);
                }
                Op::Tp(i) => {
                    cache.tp_plan(&tp_key(i), || tp_plan(i));
                    shadow.touch_or_insert(op, tp_w[i]);
                }
            }
            let stats = cache.stats();
            assert_eq!(
                stats.resident_bytes as usize, shadow.bytes,
                "seed {seed} step {step} {op:?}: resident bytes diverged \
                 (budget {budget})",
            );
            assert_eq!(
                stats.evictions, shadow.evictions,
                "seed {seed} step {step} {op:?}: eviction count diverged",
            );
            for k in 0..N_KEYS {
                assert_eq!(
                    cache.contains_dp(&dp_key(k)),
                    shadow.dp.contains_key(&k),
                    "seed {seed} step {step}: dp key {k} membership diverged",
                );
                assert_eq!(
                    cache.contains_tp(&tp_key(k)),
                    shadow.tp.contains_key(&k),
                    "seed {seed} step {step}: tp key {k} membership diverged",
                );
            }
        }
    }
}

#[test]
fn lru_handles_pathological_touch_patterns() {
    // Single hot key re-touched between every insert: the hot key must
    // survive arbitrary churn; everything else cycles.
    let probe = PlanCache::unbounded();
    probe.dp_plan(&dp_key(0), || dp_plan(0));
    let w0 = probe.stats().resident_bytes as usize;
    let cache = PlanCache::with_budget(3 * w0);
    cache.dp_plan(&dp_key(0), || dp_plan(0));
    for i in 1..50 {
        cache.dp_plan(&dp_key(0), || panic!("hot key evicted"));
        cache.dp_plan(&dp_key(i), || dp_plan(0)); // same weight as key 0
        assert!(cache.contains_dp(&dp_key(0)), "hot key gone at step {i}");
        let s = cache.stats();
        assert!(s.resident_bytes <= s.budget_bytes, "{s:?}");
    }
    assert!(cache.stats().evictions > 0);
}
