//! Differential oracle for the branch-and-bound optimizer search:
//! [`optimize`] must return the *bit-identical* winner the exhaustive
//! `SweepEngine::run_grid` + argmin oracle finds, while evaluating
//! strictly fewer scenarios (the bounds must actually prune on grids
//! designed with fat bound/actual margins). Also pins the tie-break
//! rule (equal values resolve to the smallest grid index — never
//! pruned, because pruning is on strict `bound > incumbent`) and the
//! Pareto-frontier contract in exhaustive mode.

mod common;

use std::cmp::Ordering;

use canzona::cost::optim::OptimKind;
use canzona::partition::DpStrategy;
use canzona::sim::{Breakdown, PipelineSchedule};
use canzona::sweep::{
    optimize, Objective, OptimizeOptions, OptimizeResult, SweepEngine, SweepGrid,
};
use common::{assert_bits_eq, base_grid};

/// The oracle: evaluate the whole grid, argmin by (value, grid index).
fn exhaustive_argmin(grid: &SweepGrid, obj: Objective) -> (usize, Breakdown) {
    let engine = SweepEngine::new(2);
    let (_, breakdowns) = engine.run_grid(grid);
    let mut best: Option<(f64, usize)> = None;
    for (i, b) in breakdowns.iter().enumerate() {
        let v = obj.value(b);
        assert!(v.is_finite(), "oracle hit a non-finite value at #{i}");
        let better = match best {
            None => true,
            // First strict improvement only: ties keep the earlier index.
            Some((bv, _)) => v.total_cmp(&bv) == Ordering::Less,
        };
        if better {
            best = Some((v, i));
        }
    }
    let (_, i) = best.expect("non-empty grid");
    (i, breakdowns[i].clone())
}

/// Run the search (fresh engine, pinned batch) and check the two hard
/// invariants against the oracle: bit-identical winner, strictly fewer
/// evaluations. Returns the result for extra per-grid assertions.
fn check_grid(label: &str, grid: &SweepGrid, obj: Objective) -> OptimizeResult {
    let (oracle_idx, oracle_b) = exhaustive_argmin(grid, obj);
    let engine = SweepEngine::new(2);
    let opts = OptimizeOptions { objective: obj, batch: 1, ..OptimizeOptions::default() };
    let r = optimize(&engine, grid, &opts).unwrap();
    let w = &r.evaluated[r.winner];
    assert_eq!(w.grid_index, oracle_idx, "{label}: winner index");
    assert_bits_eq(label, &oracle_b, &w.breakdown);
    assert!(
        r.evaluated.len() < r.space,
        "{label}: no pruning ({} of {} evaluated)",
        r.evaluated.len(),
        r.space
    );
    assert_eq!(r.evaluated.len() + r.pruned, r.space, "{label}: leaf accounting");
    for e in &r.evaluated {
        assert!(
            e.bound <= e.value + 1e-12,
            "{label}: inadmissible bound {} > value {} at #{}",
            e.bound,
            e.value,
            e.grid_index
        );
    }
    r
}

#[test]
fn strategy_grid_optimizer_latency() {
    // SC's bound (full redundant matrix update, ~F/gpu) dwarfs LB-ASC's
    // actual step, so the strategy axis must prune — across the full
    // zoo including the MatrixFSDP / DMuon / Dion rivals.
    let mut grid = base_grid();
    grid.strategies = DpStrategy::ALL.to_vec();
    check_grid("strategies", &grid, Objective::OptimizerLatency);
}

#[test]
fn pipeline_grid_iter_time() {
    // Micro-batches multiply total compute, so the mb=32 leaves' time
    // bounds sit far above any mb=1 actual: both must prune.
    let mut grid = base_grid();
    grid.pp = vec![1, 2];
    grid.micro_batches = vec![1, 32];
    let r = check_grid("pipeline", &grid, Objective::IterTime);
    assert!(
        r.evaluated.iter().all(|e| e.scenario.micro_batches == 1),
        "mb=32 leaves must never be evaluated"
    );
}

#[test]
fn timeline_pp_grid_optimizer_latency_prunes() {
    // Pre-PR-9 the timeline arm's optimizer-latency bound was 0, so a
    // pp>1 grid degenerated to exhaustion (strict `bound > incumbent`
    // never fires at bound 0). The min-over-stages floor now prices
    // SC's redundant full update far above LB-ASC's actual exposed
    // step (a ~dp*tp gap dwarfs the stage-split slack), so the
    // schedule × micro-batch × strategy leaves must prune while the
    // winner stays bit-identical to the exhaustive argmin.
    let mut grid = base_grid();
    grid.pp = vec![2];
    grid.micro_batches = vec![4, 8];
    grid.schedules = vec![PipelineSchedule::OneFOneB, PipelineSchedule::GPipe];
    grid.strategies = vec![DpStrategy::Sc, DpStrategy::LbAsc];
    let r = check_grid("timeline pp-grid", &grid, Objective::OptimizerLatency);
    assert!(
        r.evaluated.iter().all(|e| e.scenario.strategy == DpStrategy::LbAsc),
        "every SC leaf must be pruned by the timeline-arm bound"
    );
}

#[test]
fn optimizer_by_strategy_grid() {
    let mut grid = base_grid();
    grid.optims = vec![OptimKind::Muon, OptimKind::Shampoo];
    grid.strategies = vec![DpStrategy::Sc, DpStrategy::LbAsc];
    check_grid("optims x strategies", &grid, Objective::OptimizerLatency);
}

#[test]
fn memory_objective_grid() {
    // SC replicates the full SOAP state on every rank; its bound alone
    // (matrix state, ignoring element-wise) exceeds LB-ASC's actual
    // per-rank share, so the search must settle after one evaluation.
    let mut grid = base_grid();
    grid.optims = vec![OptimKind::Soap];
    grid.strategies = vec![DpStrategy::Sc, DpStrategy::LbAsc];
    let r = check_grid("memory", &grid, Objective::Memory);
    assert_eq!(r.evaluated.len(), 1, "SC must be pruned outright");
    assert_eq!(r.evaluated[0].scenario.strategy, DpStrategy::LbAsc);
}

#[test]
fn tie_breaks_to_smallest_grid_index() {
    // ASC ignores α entirely, so the two α leaves produce bit-identical
    // breakdowns: the winner must be the smaller grid index, and —
    // because pruning is strict — the equal-bound tied leaf must still
    // be evaluated, while the mb=32 leaves prune.
    let mut grid = base_grid();
    grid.strategies = vec![DpStrategy::Asc];
    grid.alphas = vec![0.5, 1.0];
    grid.micro_batches = vec![1, 32];
    // Axis order: micro-batches varies slower than α, so the expansion
    // is (mb=1,α=.5), (mb=1,α=1), (mb=32,α=.5), (mb=32,α=1).
    let r = check_grid("alpha tie", &grid, Objective::IterTime);
    assert_eq!(r.evaluated[r.winner].grid_index, 0, "tie must break to index 0");
    let evaluated: Vec<usize> = r.evaluated.iter().map(|e| e.grid_index).collect();
    assert_eq!(evaluated, vec![0, 1], "both tied leaves evaluated, mb=32 pruned");
    assert_bits_eq(
        "alpha-invariant ASC",
        &r.evaluated[0].breakdown,
        &r.evaluated[1].breakdown,
    );
}

#[test]
fn exhaustive_mode_frontier_is_pareto_exact() {
    let mut grid = base_grid();
    grid.strategies = DpStrategy::ALL.to_vec();
    grid.optims = vec![OptimKind::Muon, OptimKind::Shampoo];
    let engine = SweepEngine::new(2);
    let opts = OptimizeOptions {
        objective: Objective::IterTime,
        prune: false,
        batch: 1,
        ..OptimizeOptions::default()
    };
    let r = optimize(&engine, &grid, &opts).unwrap();
    assert_eq!(r.evaluated.len(), r.space, "exhaustive mode evaluates everything");
    assert_eq!(r.pruned, 0);
    assert!(!r.frontier.is_empty());
    assert!(r.frontier.contains(&r.winner));

    let metric = |b: &Breakdown| {
        let mem = b.dp_loads_state.iter().cloned().fold(0.0, f64::max);
        let bub = if b.fwd_bwd_s > 0.0 { b.bubble_s / b.fwd_bwd_s } else { 0.0 };
        [b.total_s, mem, bub]
    };
    let dominates = |a: &[f64; 3], b: &[f64; 3]| {
        a.iter().zip(b).all(|(x, y)| x <= y) && a.iter().zip(b).any(|(x, y)| x < y)
    };
    let ms: Vec<[f64; 3]> = r.evaluated.iter().map(|e| metric(&e.breakdown)).collect();
    // Frontier members are non-dominated (the winner is force-included
    // and may only be dominated by an objective-tied leaf).
    for &i in &r.frontier {
        for (j, mj) in ms.iter().enumerate() {
            if j != i && dominates(mj, &ms[i]) {
                assert_eq!(i, r.winner, "frontier #{i} dominated by #{j}");
                assert_eq!(
                    r.evaluated[j].value.to_bits(),
                    r.evaluated[i].value.to_bits(),
                    "only an objective tie can dominate the winner"
                );
            }
        }
    }
    // Every excluded leaf is dominated or a duplicate of a kept one.
    for i in 0..ms.len() {
        if r.frontier.contains(&i) {
            continue;
        }
        let excluded_ok = ms
            .iter()
            .enumerate()
            .any(|(j, mj)| (j != i && dominates(mj, &ms[i])) || (j < i && *mj == ms[i]));
        assert!(excluded_ok, "leaf #{i} excluded from the frontier but undominated");
    }
}

#[test]
fn pruned_mode_frontier_is_subset_and_internally_consistent() {
    let mut grid = base_grid();
    grid.pp = vec![1, 2];
    grid.micro_batches = vec![1, 32];
    grid.strategies = vec![DpStrategy::NvLayerwise, DpStrategy::LbAsc];
    let engine = SweepEngine::new(2);
    let opts = OptimizeOptions {
        objective: Objective::IterTime,
        batch: 1,
        ..OptimizeOptions::default()
    };
    let r = optimize(&engine, &grid, &opts).unwrap();
    assert!(r.frontier.iter().all(|&i| i < r.evaluated.len()));
    assert!(r.frontier.contains(&r.winner));
    assert!(r.frontier.windows(2).all(|w| w[0] < w[1]), "frontier sorted");
}
