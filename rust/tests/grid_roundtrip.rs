//! Property sweep: `SweepGrid::to_cli_args` → `Args::parse` →
//! `SweepGrid::parse` is the identity on every representable grid.
//! Float axes ride Rust's shortest round-trip `Display`, so the
//! recovered grid compares equal bit-for-bit, not merely approximately;
//! enum axes round-trip through their lowercased labels; the integer
//! axes additionally accept the `lo..hi` inclusive-range sugar, which
//! must expand to the same list as the explicit comma form.

use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{FailSpec, HeteroSpec, PipelineSchedule};
use canzona::sweep::SweepGrid;
use canzona::util::cli::Args;
use canzona::util::prop::check;
use canzona::util::rng::Rng;

/// A non-empty multiset drawn from `domain` (duplicates and arbitrary
/// order are representable on the CLI, so the generator produces them).
fn pick<T: Clone>(rng: &mut Rng, domain: &[T]) -> Vec<T> {
    let n = 1 + rng.index(domain.len());
    (0..n).map(|_| domain[rng.index(domain.len())].clone()).collect()
}

/// A random *canonical* hetero spec: rates are nonzero and factors
/// exceed 1, so the generated value is exactly what `parse` would
/// canonicalize its own `Display` to.
fn random_hetero(rng: &mut Rng) -> HeteroSpec {
    let rate = |rng: &mut Rng| (1 + rng.index(100)) as f64 / 100.0;
    let factor = |rng: &mut Rng| 1.0 + (1 + rng.index(40)) as f64 / 8.0;
    match rng.index(5) {
        0 => HeteroSpec::None,
        1 => HeteroSpec::LastStage { factor: factor(rng) },
        2 => HeteroSpec::Mix {
            slow_rate: rate(rng),
            slow_factor: factor(rng),
            link_rate: 0.0,
            link_factor: 1.0,
        },
        3 => HeteroSpec::Mix {
            slow_rate: 0.0,
            slow_factor: 1.0,
            link_rate: rate(rng),
            link_factor: factor(rng),
        },
        _ => HeteroSpec::Mix {
            slow_rate: rate(rng),
            slow_factor: factor(rng),
            link_rate: rate(rng),
            link_factor: factor(rng),
        },
    }
}

fn random_grid(rng: &mut Rng) -> SweepGrid {
    let dims = |rng: &mut Rng| -> Vec<usize> {
        let n = 1 + rng.index(4);
        (0..n).map(|_| rng.range(1, 65) as usize).collect()
    };
    SweepGrid {
        models: pick(rng, &Qwen3Size::all()),
        dp: dims(rng),
        tp: dims(rng),
        pp: dims(rng),
        micro_batches: dims(rng),
        schedules: pick(rng, &[PipelineSchedule::OneFOneB, PipelineSchedule::GPipe]),
        stragglers: (0..1 + rng.index(3)).map(|_| 1.0 + 3.0 * rng.next_f64()).collect(),
        optims: pick(
            rng,
            &[OptimKind::Muon, OptimKind::Shampoo, OptimKind::Soap, OptimKind::AdamW],
        ),
        strategies: pick(
            rng,
            &[DpStrategy::Sc, DpStrategy::NvLayerwise, DpStrategy::Asc, DpStrategy::LbAsc],
        ),
        alphas: (0..1 + rng.index(3)).map(|_| rng.next_f64()).collect(),
        c_max_mb: (0..1 + rng.index(3))
            .map(|_| {
                if rng.index(3) == 0 {
                    None
                } else {
                    // Strictly positive: "0" is the CLI spelling of None.
                    Some(0.5 + 1024.0 * rng.next_f64())
                }
            })
            .collect(),
        heteros: (0..1 + rng.index(3)).map(|_| random_hetero(rng)).collect(),
        fail_ranks: (0..1 + rng.index(3))
            .map(|_| {
                if rng.index(2) == 0 {
                    None
                } else {
                    Some(FailSpec { rank: rng.index(256), at: rng.index(10) as f64 / 10.0 })
                }
            })
            .collect(),
        mttfs: (0..1 + rng.index(3))
            .map(|_| {
                if rng.index(2) == 0 { None } else { Some((1 + rng.index(7200)) as f64) }
            })
            .collect(),
        ckpt_intervals: (0..1 + rng.index(3)).map(|_| 1 + rng.index(32)).collect(),
        metric: [CostMetric::Numel, CostMetric::Flops, CostMetric::StateBytes][rng.index(3)],
        fault_seed: rng.range(0, 1_000_000),
    }
}

fn reparse(g: &SweepGrid) -> Result<SweepGrid, String> {
    let cli = g.to_cli_args();
    let args = Args::parse(cli.into_iter(), &[]).map_err(|e| e.to_string())?;
    SweepGrid::parse(&args).map_err(|e| e.to_string())
}

#[test]
fn cli_round_trip_is_identity_on_random_grids() {
    check("grid-cli-round-trip", 200, random_grid, |g| {
        let back = reparse(g)?;
        if back == *g {
            Ok(())
        } else {
            Err(format!("re-parsed grid diverged:\n  back: {back:?}"))
        }
    });
}

#[test]
fn round_trip_is_stable_under_iteration() {
    // to_cli_args of a re-parsed grid is byte-identical to the first
    // rendering: the canonical form is a fixed point, so artifacts that
    // embed the argument list reproduce exactly.
    check("grid-cli-fixed-point", 50, random_grid, |g| {
        let back = reparse(g)?;
        let a = g.to_cli_args();
        let b = back.to_cli_args();
        if a == b {
            Ok(())
        } else {
            Err(format!("canonical args drifted:\n  first:  {a:?}\n  second: {b:?}"))
        }
    });
}

fn parse_cli(s: &str) -> Result<SweepGrid, String> {
    let args = Args::parse(s.split_whitespace().map(|x| x.to_string()), &[])
        .map_err(|e| e.to_string())?;
    SweepGrid::parse(&args).map_err(|e| e.to_string())
}

#[test]
fn range_sugar_expands_to_the_explicit_list() {
    let sugar = parse_cli("--dp 1,4..6,16 --tp 2..2 --pp 1..3").unwrap();
    let explicit = parse_cli("--dp 1,4,5,6,16 --tp 2 --pp 1,2,3").unwrap();
    assert_eq!(sugar, explicit);
    // ...and the canonical rendering of a range-built grid re-parses to
    // the same grid (ranges are sugar, not state).
    assert_eq!(reparse(&sugar).unwrap(), sugar);
}

#[test]
fn malformed_axes_are_rejected_with_named_errors() {
    for (what, cli, needle) in [
        ("empty segment", "--dp 1,,2", "dp"),
        ("inverted range", "--tp 6..4", "tp"),
        ("zero dimension", "--pp 0..2", "pp"),
        ("open-ended range", "--micro-batches 1..", "micro-batches"),
        ("sub-unit straggler", "--straggler 0.5", "straggler"),
        ("out-of-range alpha", "--alphas 1.5", "alphas"),
        ("negative capacity", "--c-max-mb -3", "c-max-mb"),
        ("unknown metric", "--metric bytes", "metric"),
        ("unknown model", "--models 70b", "models"),
        ("malformed hetero spec", "--hetero bogus", "hetero"),
        ("out-of-range hetero rate", "--hetero slow:2:1.5", "hetero"),
        ("out-of-range failure position", "--fail-rank 3@2", "fail-rank"),
        ("non-numeric failure rank", "--fail-rank x@0.5", "fail-rank"),
        ("zero mttf", "--mttf 0", "mttf"),
        ("non-finite mttf", "--mttf nan", "mttf"),
        ("zero checkpoint interval", "--ckpt-interval 0", "ckpt-interval"),
        ("non-numeric fault seed", "--fault-seed abc", "fault-seed"),
    ] {
        let err = parse_cli(cli).expect_err(what);
        assert!(err.contains(needle), "{what}: error {err:?} should name {needle:?}");
    }
}

#[test]
fn declared_flags_reject_eq_values_at_the_cli_boundary() {
    // The sweep/optimize entry points declare their boolean flags, so
    // `--no-batch=1` must be a parse error rather than a silently
    // ignored option.
    let flags = ["verbose", "csv", "exhaustive", "no-batch"];
    for flag in flags {
        let argv = vec![format!("--{flag}=1")];
        let err = Args::parse(argv.into_iter(), &flags).expect_err(flag).to_string();
        assert!(
            err.contains("takes no value"),
            "--{flag}=1: unexpected message {err:?}"
        );
    }
}
