//! Differential oracle for the batched SoA evaluator: every lane of a
//! [`ScenarioBatch`] must be **bit-for-bit** identical (every
//! `Breakdown` field except `planning_s`, which is wall-clock cache
//! latency) to the scalar `simulate_iteration_cached` run on the
//! equivalent standalone [`Scenario`] — across every strategy ×
//! optimizer × size × TP × fusion point in the oracle grid, with
//! randomized per-lane knob vectors (bandwidths, latencies, launch
//! overhead, straggler derate, C_max) and ragged batch lengths
//! straddling the fixed-width chunk boundary (`1..=BATCH_CHUNK + 1`).
//!
//! The lane-knob → scalar-scenario equivalence is: the oracle scenario
//! carries the lane's hardware profile *pre-derated* by the lane
//! straggler and `straggler = 1.0`, because the batch path folds the
//! lane straggler into its effective hardware while the scalar
//! dispatcher would route `straggler != 1.0` to the timeline engine.

mod common;

use canzona::cost::hardware::Hardware;
use canzona::sim::{
    simulate_batch_into, simulate_iteration_cached, Breakdown, BreakdownBatch, LaneKnobs,
    Scenario, ScenarioBatch, BATCH_CHUNK,
};
use canzona::sweep::PlanCache;
use canzona::util::rng::Rng;
use common::{assert_bits_eq, oracle_grid};

/// The standalone scenario whose scalar evaluation the batch lane must
/// reproduce bit-for-bit: lane knobs over the base hardware identity,
/// derated by the lane straggler, with `straggler = 1.0` so the scalar
/// dispatcher keeps it on the closed-form arm.
fn oracle_scenario(base: &Scenario, k: &LaneKnobs) -> Scenario {
    let mut s = base.clone();
    s.c_max_bytes = k.c_max_bytes;
    s.hw = Hardware {
        gpu_flops: k.gpu_flops,
        hbm_bw: k.hbm_bw,
        nvlink_bw: k.nvlink_bw,
        ib_bw: k.ib_bw,
        nvlink_lat: k.nvlink_lat,
        ib_lat: k.ib_lat,
        launch_overhead: k.launch_overhead,
        ..s.hw.clone()
    }
    .derate(k.straggler);
    s.straggler = 1.0;
    s
}

/// A random lane: every continuous knob perturbed away from the base
/// profile, including a straggler derate and a fusion-capacity draw
/// that crosses the None / Some boundary.
fn perturbed_lane(rng: &mut Rng, base: &Scenario) -> LaneKnobs {
    let mut k = LaneKnobs::from_scenario(base);
    let scale = |rng: &mut Rng| 0.5 + 1.5 * rng.next_f64(); // [0.5, 2.0)
    k.gpu_flops *= scale(rng);
    k.hbm_bw *= scale(rng);
    k.nvlink_bw *= scale(rng);
    k.ib_bw *= scale(rng);
    k.nvlink_lat *= 2.0 * rng.next_f64(); // [0, 2x) — zero latency is legal
    k.ib_lat *= 2.0 * rng.next_f64();
    k.launch_overhead *= 2.0 * rng.next_f64();
    k.straggler = 1.0 + rng.next_f64(); // [1.0, 2.0)
    k.c_max_bytes = match rng.index(3) {
        0 => None,
        1 => Some((64.0 + 448.0 * rng.next_f64()) * 1024.0 * 1024.0), // 64..512 MB
        _ => k.c_max_bytes,
    };
    k
}

/// Evaluate `batch` and compare every lane's scattered `Breakdown`
/// against the scalar oracle on the *same* cache (the engine's
/// operating mode: plans and tables are shared Arcs either way).
fn check_batch_against_scalar(label: &str, batch: &ScenarioBatch, cache: &PlanCache) {
    let mut out = BreakdownBatch::new();
    simulate_batch_into(batch, cache, &mut out);
    assert_eq!(out.len(), batch.len(), "{label}: output length");
    for (lane, knobs) in batch.lanes().iter().enumerate() {
        let mut got = Breakdown::default();
        out.write_into(batch, lane, &mut got);
        let oracle = oracle_scenario(batch.base(), knobs);
        let want = simulate_iteration_cached(&oracle, cache);
        assert_bits_eq(&format!("{label} lane {lane}"), &want, &got);
    }
}

#[test]
fn batched_lanes_match_scalar_bits_across_oracle_grid() {
    let cache = PlanCache::unbounded();
    let mut rng = Rng::new(0xBA7C4_D1FF);
    for (i, s) in oracle_grid().scenarios().into_iter().enumerate() {
        let label = format!(
            "{} tp{} {} {} c_max={:?}",
            s.label,
            s.tp,
            s.optim.label(),
            s.strategy.label(),
            s.c_max_bytes,
        );
        let mut batch = ScenarioBatch::new(s.clone()).expect("oracle grid is closed-form");
        // Lane 0 is the identity lane (the base scenario itself); the
        // rest are random draws. Lengths cycle 1..=BATCH_CHUNK + 1 so
        // every ragged tail (including the empty tail and a full chunk
        // plus one) appears across the grid.
        let lanes = 1 + i % (BATCH_CHUNK + 1);
        batch.push_scenario(&s).expect("identity lane");
        for _ in 1..lanes {
            batch.push(perturbed_lane(&mut rng, &s)).expect("perturbed lane");
        }
        check_batch_against_scalar(&label, &batch, &cache);
    }
}

#[test]
fn every_ragged_tail_length_matches_scalar_bits() {
    // One fixed base, every batch length 1..=2*BATCH_CHUNK + 1: the
    // chunked inner loops must agree with the scalar path on full
    // chunks, partial tails, and the one-past-a-chunk boundary alike.
    let cache = PlanCache::unbounded();
    let mut rng = Rng::new(0x7A11_5EED);
    let grid = oracle_grid();
    let base = grid.scenarios().into_iter().next().expect("non-empty grid");
    for n in 1..=2 * BATCH_CHUNK + 1 {
        let mut batch = ScenarioBatch::new(base.clone()).expect("closed-form base");
        for lane in 0..n {
            if lane == 0 {
                batch.push_scenario(&base).expect("identity lane");
            } else {
                batch.push(perturbed_lane(&mut rng, &base)).expect("perturbed lane");
            }
        }
        check_batch_against_scalar(&format!("len={n}"), &batch, &cache);
    }
}

#[test]
fn identity_lanes_match_scalar_bits_on_a_cold_cache() {
    // Plans solved by the batch path and by the scalar path on separate
    // caches must still agree bit-for-bit: the solves themselves are
    // deterministic, not merely Arc-shared.
    let grid = oracle_grid();
    for s in grid.scenarios().into_iter().take(8) {
        let mut batch = ScenarioBatch::new(s.clone()).expect("closed-form base");
        batch.push_scenario(&s).expect("identity lane");
        let batch_cache = PlanCache::unbounded();
        let mut out = BreakdownBatch::new();
        simulate_batch_into(&batch, &batch_cache, &mut out);
        let mut got = Breakdown::default();
        out.write_into(&batch, 0, &mut got);
        let scalar_cache = PlanCache::unbounded();
        let want = simulate_iteration_cached(&s, &scalar_cache);
        assert_bits_eq(&format!("cold {}", s.label), &want, &got);
    }
}

#[test]
fn non_closed_form_bases_are_rejected_at_construction() {
    let grid = oracle_grid();
    let base = grid.scenarios().into_iter().next().expect("non-empty grid");
    let mut pp2 = base.clone();
    pp2.pp = 2;
    for (what, s) in [
        ("pp=2", pp2),
        ("micro_batches=4", base.clone().with_micro_batches(4)),
        ("straggler=1.5", base.clone().with_straggler(1.5)),
    ] {
        let err = ScenarioBatch::new(s).expect_err(what).to_string();
        assert!(err.contains("closed-form"), "{what}: unexpected message {err:?}");
    }
}

#[test]
fn poisoned_lane_knobs_are_rejected_at_push() {
    let grid = oracle_grid();
    let base = grid.scenarios().into_iter().next().expect("non-empty grid");
    let mut batch = ScenarioBatch::new(base.clone()).expect("closed-form base");
    let poison: &[(&str, fn(&mut LaneKnobs))] = &[
        ("zero ib_bw", |k| k.ib_bw = 0.0),
        ("nan hbm_bw", |k| k.hbm_bw = f64::NAN),
        ("negative latency", |k| k.nvlink_lat = -1e-6),
        ("sub-unit straggler", |k| k.straggler = 0.5),
        ("zero c_max", |k| k.c_max_bytes = Some(0.0)),
        ("inf c_max", |k| k.c_max_bytes = Some(f64::INFINITY)),
    ];
    for &(what, poison) in poison {
        let mut k = LaneKnobs::from_scenario(&base);
        poison(&mut k);
        let err = batch.push(k).expect_err(what).to_string();
        assert!(err.contains("invalid scenario:"), "{what}: unexpected message {err:?}");
    }
    assert!(batch.is_empty(), "rejected lanes must not be admitted");
}
