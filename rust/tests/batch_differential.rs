//! Differential oracle for the batched SoA evaluator: every lane of a
//! [`ScenarioBatch`] must be **bit-for-bit** identical (every
//! `Breakdown` field except `planning_s`, which is wall-clock cache
//! latency) to the scalar `simulate_iteration_cached` run on the
//! equivalent standalone [`Scenario`] — across every strategy ×
//! optimizer × size × TP × fusion point in the oracle grid, with
//! randomized per-lane knob vectors (bandwidths, latencies, launch
//! overhead, straggler derate, C_max) and ragged batch lengths
//! straddling the fixed-width chunk boundary (`1..=BATCH_CHUNK + 1`).
//!
//! The lane-knob → scalar-scenario equivalence differs per arm. On the
//! **closed-form** arm the oracle scenario carries the lane's hardware
//! profile *pre-derated* by the lane straggler and `straggler = 1.0`,
//! because the batch path folds the lane straggler into its effective
//! hardware while the scalar dispatcher would route `straggler != 1.0`
//! to the timeline engine. On the **timeline** (schedule-tape) arm the
//! oracle carries the raw lane profile and `straggler = k.straggler`
//! verbatim — the scalar timeline derates only the last stage and
//! prices the fabric un-derated, and the tape replay must reproduce
//! exactly that (pp ∈ {2,4,8} × {1f1b, gpipe} × micro-batches ×
//! straggler, rivals included).

mod common;

use canzona::cost::hardware::Hardware;
use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{
    simulate_batch_into, simulate_iteration_cached, simulate_timeline_batch_into, Breakdown,
    BreakdownBatch, LaneKnobs, PipelineSchedule, Scenario, ScenarioBatch, BATCH_CHUNK,
};
use canzona::sweep::{PlanCache, SweepGrid};
use canzona::util::rng::Rng;
use common::{assert_bits_eq, oracle_grid};

/// The standalone scenario whose scalar evaluation the batch lane must
/// reproduce bit-for-bit: lane knobs over the base hardware identity,
/// derated by the lane straggler, with `straggler = 1.0` so the scalar
/// dispatcher keeps it on the closed-form arm.
fn oracle_scenario(base: &Scenario, k: &LaneKnobs) -> Scenario {
    let mut s = base.clone();
    s.c_max_bytes = k.c_max_bytes;
    s.hw = Hardware {
        gpu_flops: k.gpu_flops,
        hbm_bw: k.hbm_bw,
        nvlink_bw: k.nvlink_bw,
        ib_bw: k.ib_bw,
        nvlink_lat: k.nvlink_lat,
        ib_lat: k.ib_lat,
        launch_overhead: k.launch_overhead,
        ..s.hw.clone()
    }
    .derate(k.straggler);
    s.straggler = 1.0;
    s
}

/// A random lane: every continuous knob perturbed away from the base
/// profile, including a straggler derate and a fusion-capacity draw
/// that crosses the None / Some boundary.
fn perturbed_lane(rng: &mut Rng, base: &Scenario) -> LaneKnobs {
    let mut k = LaneKnobs::from_scenario(base);
    let scale = |rng: &mut Rng| 0.5 + 1.5 * rng.next_f64(); // [0.5, 2.0)
    k.gpu_flops *= scale(rng);
    k.hbm_bw *= scale(rng);
    k.nvlink_bw *= scale(rng);
    k.ib_bw *= scale(rng);
    k.nvlink_lat *= 2.0 * rng.next_f64(); // [0, 2x) — zero latency is legal
    k.ib_lat *= 2.0 * rng.next_f64();
    k.launch_overhead *= 2.0 * rng.next_f64();
    k.straggler = 1.0 + rng.next_f64(); // [1.0, 2.0)
    k.c_max_bytes = match rng.index(3) {
        0 => None,
        1 => Some((64.0 + 448.0 * rng.next_f64()) * 1024.0 * 1024.0), // 64..512 MB
        _ => k.c_max_bytes,
    };
    k
}

/// The timeline arm's standalone-scenario equivalence: the *raw* lane
/// profile (not derated) with the lane straggler carried verbatim —
/// the scalar timeline dispatcher derates only the last stage and
/// prices collectives against the un-derated fabric, exactly as the
/// tape replay does.
fn timeline_oracle_scenario(base: &Scenario, k: &LaneKnobs) -> Scenario {
    let mut s = base.clone();
    s.c_max_bytes = k.c_max_bytes;
    s.hw = Hardware {
        gpu_flops: k.gpu_flops,
        hbm_bw: k.hbm_bw,
        nvlink_bw: k.nvlink_bw,
        ib_bw: k.ib_bw,
        nvlink_lat: k.nvlink_lat,
        ib_lat: k.ib_lat,
        launch_overhead: k.launch_overhead,
        ..s.hw.clone()
    };
    s.straggler = k.straggler;
    s
}

/// Evaluate `batch` and compare every lane's scattered `Breakdown`
/// against the scalar oracle on the *same* cache (the engine's
/// operating mode: plans and tables are shared Arcs either way).
fn check_batch_against_scalar(label: &str, batch: &ScenarioBatch, cache: &PlanCache) {
    let mut out = BreakdownBatch::new();
    simulate_batch_into(batch, cache, &mut out);
    assert_eq!(out.len(), batch.len(), "{label}: output length");
    for (lane, knobs) in batch.lanes().iter().enumerate() {
        let mut got = Breakdown::default();
        out.write_into(batch, lane, &mut got);
        let oracle = oracle_scenario(batch.base(), knobs);
        let want = simulate_iteration_cached(&oracle, cache);
        assert_bits_eq(&format!("{label} lane {lane}"), &want, &got);
    }
}

/// Timeline-arm counterpart of [`check_batch_against_scalar`]: drives
/// the schedule-tape entry point directly and compares against the
/// scalar timeline playback of each lane's equivalent scenario.
fn check_timeline_batch_against_scalar(label: &str, batch: &ScenarioBatch, cache: &PlanCache) {
    let mut out = BreakdownBatch::new();
    simulate_timeline_batch_into(batch, cache, &mut out);
    assert_eq!(out.len(), batch.len(), "{label}: output length");
    for (lane, knobs) in batch.lanes().iter().enumerate() {
        let mut got = Breakdown::default();
        out.write_into(batch, lane, &mut got);
        let oracle = timeline_oracle_scenario(batch.base(), knobs);
        let want = simulate_iteration_cached(&oracle, cache);
        assert_bits_eq(&format!("{label} lane {lane}"), &want, &got);
    }
}

/// The timeline-arm coverage grid: every pipeline depth the tape's
/// stage machinery branches on (2 / interior-stage 4 / deep 8), both
/// schedules, micro-batching on and off, a straggling last stage, and
/// the full strategy zoo (rivals included).
fn timeline_grid() -> SweepGrid {
    SweepGrid {
        models: vec![Qwen3Size::S1_7B],
        dp: vec![4],
        tp: vec![2],
        pp: vec![2, 4, 8],
        micro_batches: vec![1, 4],
        schedules: vec![PipelineSchedule::OneFOneB, PipelineSchedule::GPipe],
        stragglers: vec![1.0, 1.3],
        optims: vec![OptimKind::Muon],
        strategies: DpStrategy::ALL.to_vec(),
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0)],
        heteros: vec![canzona::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    }
}

#[test]
fn batched_lanes_match_scalar_bits_across_oracle_grid() {
    let cache = PlanCache::unbounded();
    let mut rng = Rng::new(0xBA7C4_D1FF);
    for (i, s) in oracle_grid().scenarios().into_iter().enumerate() {
        let label = format!(
            "{} tp{} {} {} c_max={:?}",
            s.label,
            s.tp,
            s.optim.label(),
            s.strategy.label(),
            s.c_max_bytes,
        );
        let mut batch = ScenarioBatch::new(s.clone()).expect("oracle grid is closed-form");
        // Lane 0 is the identity lane (the base scenario itself); the
        // rest are random draws. Lengths cycle 1..=BATCH_CHUNK + 1 so
        // every ragged tail (including the empty tail and a full chunk
        // plus one) appears across the grid.
        let lanes = 1 + i % (BATCH_CHUNK + 1);
        batch.push_scenario(&s).expect("identity lane");
        for _ in 1..lanes {
            batch.push(perturbed_lane(&mut rng, &s)).expect("perturbed lane");
        }
        check_batch_against_scalar(&label, &batch, &cache);
    }
}

#[test]
fn every_ragged_tail_length_matches_scalar_bits() {
    // One fixed base, every batch length 1..=2*BATCH_CHUNK + 1: the
    // chunked inner loops must agree with the scalar path on full
    // chunks, partial tails, and the one-past-a-chunk boundary alike.
    let cache = PlanCache::unbounded();
    let mut rng = Rng::new(0x7A11_5EED);
    let grid = oracle_grid();
    let base = grid.scenarios().into_iter().next().expect("non-empty grid");
    for n in 1..=2 * BATCH_CHUNK + 1 {
        let mut batch = ScenarioBatch::new(base.clone()).expect("closed-form base");
        for lane in 0..n {
            if lane == 0 {
                batch.push_scenario(&base).expect("identity lane");
            } else {
                batch.push(perturbed_lane(&mut rng, &base)).expect("perturbed lane");
            }
        }
        check_batch_against_scalar(&format!("len={n}"), &batch, &cache);
    }
}

#[test]
fn identity_lanes_match_scalar_bits_on_a_cold_cache() {
    // Plans solved by the batch path and by the scalar path on separate
    // caches must still agree bit-for-bit: the solves themselves are
    // deterministic, not merely Arc-shared.
    let grid = oracle_grid();
    for s in grid.scenarios().into_iter().take(8) {
        let mut batch = ScenarioBatch::new(s.clone()).expect("closed-form base");
        batch.push_scenario(&s).expect("identity lane");
        let batch_cache = PlanCache::unbounded();
        let mut out = BreakdownBatch::new();
        simulate_batch_into(&batch, &batch_cache, &mut out);
        let mut got = Breakdown::default();
        out.write_into(&batch, 0, &mut got);
        let scalar_cache = PlanCache::unbounded();
        let want = simulate_iteration_cached(&s, &scalar_cache);
        assert_bits_eq(&format!("cold {}", s.label), &want, &got);
    }
}

#[test]
fn timeline_lanes_match_scalar_bits_across_pp_schedule_grid() {
    // The PR 9 oracle: every schedule-tape lane bit-identical to the
    // scalar timeline playback across pp × schedule × micro-batches ×
    // straggler × strategy (rivals included), with randomized lane
    // knobs and ragged batch lengths straddling the chunk boundary.
    let cache = PlanCache::unbounded();
    let mut rng = Rng::new(0x7AE5_C0DE);
    for (i, s) in timeline_grid().scenarios().into_iter().enumerate() {
        let label = format!(
            "{} pp{} mb{} {} strag{} {}",
            s.label,
            s.pp,
            s.micro_batches,
            s.schedule.label(),
            s.straggler,
            s.strategy.label(),
        );
        let mut batch = ScenarioBatch::new(s.clone()).expect("timeline base accepted");
        let lanes = 1 + i % (BATCH_CHUNK + 1);
        batch.push_scenario(&s).expect("identity lane");
        for _ in 1..lanes {
            batch.push(perturbed_lane(&mut rng, &s)).expect("perturbed lane");
        }
        check_timeline_batch_against_scalar(&label, &batch, &cache);
    }
}

#[test]
fn timeline_every_ragged_tail_length_matches_scalar_bits() {
    // One deep-pipeline micro-batched base, every batch length
    // 1..=2*BATCH_CHUNK + 1: full chunks, partial tails, and the
    // one-past-a-chunk boundary must all replay identically.
    let cache = PlanCache::unbounded();
    let mut rng = Rng::new(0x7A11_7A9E);
    let base = timeline_grid()
        .scenarios()
        .into_iter()
        .find(|s| s.pp == 4 && s.micro_batches == 4 && s.straggler != 1.0)
        .expect("grid has a pp=4 mb=4 straggler point");
    for n in 1..=2 * BATCH_CHUNK + 1 {
        let mut batch = ScenarioBatch::new(base.clone()).expect("timeline base accepted");
        for lane in 0..n {
            if lane == 0 {
                batch.push_scenario(&base).expect("identity lane");
            } else {
                batch.push(perturbed_lane(&mut rng, &base)).expect("perturbed lane");
            }
        }
        check_timeline_batch_against_scalar(&format!("len={n}"), &batch, &cache);
    }
}

#[test]
fn timeline_identity_lanes_match_scalar_bits_on_a_cold_cache() {
    // Tapes recorded by the batch path and schedules emitted by the
    // scalar path on separate cold caches must still agree bit-for-bit:
    // the tape recording is deterministic, not merely state-shared.
    for s in timeline_grid().scenarios().into_iter().step_by(17) {
        let mut batch = ScenarioBatch::new(s.clone()).expect("timeline base accepted");
        batch.push_scenario(&s).expect("identity lane");
        let batch_cache = PlanCache::unbounded();
        let mut out = BreakdownBatch::new();
        simulate_timeline_batch_into(&batch, &batch_cache, &mut out);
        let mut got = Breakdown::default();
        out.write_into(&batch, 0, &mut got);
        let scalar_cache = PlanCache::unbounded();
        let want = simulate_iteration_cached(&s, &scalar_cache);
        assert_bits_eq(
            &format!("cold {} pp{} {}", s.label, s.pp, s.schedule.label()),
            &want,
            &got,
        );
    }
}

#[test]
fn non_closed_form_bases_are_accepted_and_dispatched() {
    // Pre-PR-9 these were construction errors; both arms are now
    // eligible, and `simulate_batch_into` routes by the base's arm.
    let grid = oracle_grid();
    let base = grid.scenarios().into_iter().next().expect("non-empty grid");
    let mut pp2 = base.clone();
    pp2.pp = 2;
    for (what, s) in [
        ("pp=2", pp2),
        ("micro_batches=4", base.clone().with_micro_batches(4)),
        ("straggler=1.5", base.clone().with_straggler(1.5)),
    ] {
        let mut batch = ScenarioBatch::new(s.clone()).expect(what);
        batch.push_scenario(&s).expect(what);
        let cache = PlanCache::unbounded();
        check_timeline_batch_against_scalar(what, &batch, &cache);
        assert_eq!(cache.stats().batched_timeline_evals, 1, "{what}: counter");
    }
}

#[test]
fn poisoned_lane_knobs_are_rejected_at_push() {
    let grid = oracle_grid();
    let base = grid.scenarios().into_iter().next().expect("non-empty grid");
    let mut batch = ScenarioBatch::new(base.clone()).expect("closed-form base");
    let poison: &[(&str, fn(&mut LaneKnobs))] = &[
        ("zero ib_bw", |k| k.ib_bw = 0.0),
        ("nan hbm_bw", |k| k.hbm_bw = f64::NAN),
        ("negative latency", |k| k.nvlink_lat = -1e-6),
        ("sub-unit straggler", |k| k.straggler = 0.5),
        ("zero c_max", |k| k.c_max_bytes = Some(0.0)),
        ("inf c_max", |k| k.c_max_bytes = Some(f64::INFINITY)),
    ];
    for &(what, poison) in poison {
        let mut k = LaneKnobs::from_scenario(&base);
        poison(&mut k);
        let err = batch.push(k).expect_err(what).to_string();
        assert!(err.contains("invalid scenario:"), "{what}: unexpected message {err:?}");
    }
    assert!(batch.is_empty(), "rejected lanes must not be admitted");
}
