//! Artifact round-trip tests: load the AOT HLO-text executables via PJRT
//! and verify their numerics against Rust-side reference math.
//!
//! Requires `make artifacts` (the tiny preset). Tests skip (pass
//! trivially with a notice) when artifacts are absent so `cargo test`
//! works on a fresh checkout.

use std::path::PathBuf;

use canzona::runtime::{literal_f32, literal_i32, literal_scalar, to_f32_vec, Manifest, Runtime};
use canzona::util::rng::Rng;

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest__tiny.json").exists()
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn manifest_loads_and_is_consistent() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir(), "tiny").unwrap();
    assert_eq!(m.preset, "tiny");
    assert_eq!(m.model.vocab, 256);
    assert_eq!(m.params.len(), 3 + m.model.n_layers * 9);
    assert!(m.muon_lr > 0.0 && m.muon_lr < 1.0);
    for p in &m.params {
        assert!(m.artifact_file(&p.artifact).is_ok(), "{}", p.name);
        assert_eq!(p.numel, p.shape.iter().product::<usize>());
    }
    assert_eq!(m.total_params(), m.census().iter().map(|p| p.numel()).sum());
}

#[test]
fn fwd_bwd_artifact_executes_and_is_deterministic() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir(), "tiny").unwrap();
    let mut rt = Runtime::new(&artifacts_dir()).unwrap();
    let file = m.artifact_file("fwd_bwd").unwrap().to_string();

    let mut rng = Rng::new(7);
    let mut inputs = Vec::new();
    for p in &m.params {
        let mut data = vec![0.0f32; p.numel];
        if p.init_std == 0.0 {
            data.fill(1.0);
        } else {
            rng.fill_normal_f32(&mut data, p.init_std as f32);
        }
        let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();
        inputs.push(literal_f32(&data, &dims).unwrap());
    }
    let bs = [m.model.batch as i64, m.model.seq_len as i64];
    let tokens: Vec<i32> = (0..m.model.batch * m.model.seq_len)
        .map(|i| (i % m.model.vocab) as i32)
        .collect();
    inputs.push(literal_i32(&tokens, &bs).unwrap());
    inputs.push(literal_i32(&tokens, &bs).unwrap());

    let out1 = rt.execute(&file, &inputs).unwrap();
    assert_eq!(out1.len(), m.params.len() + 1);
    let loss = out1[0].to_vec::<f32>().unwrap()[0];
    // Fresh random params => loss near ln(vocab).
    assert!((loss - (m.model.vocab as f32).ln()).abs() < 1.0, "loss {loss}");
    // Gradients: right shapes, finite, not all zero.
    let mut nonzero = 0;
    for (i, g) in out1[1..].iter().enumerate() {
        let v = to_f32_vec(g).unwrap();
        assert_eq!(v.len(), m.params[i].numel, "{}", m.params[i].name);
        assert!(v.iter().all(|x| x.is_finite()), "{}", m.params[i].name);
        if v.iter().any(|&x| x != 0.0) {
            nonzero += 1;
        }
    }
    assert!(nonzero > m.params.len() / 2);

    // Determinism: same inputs -> bitwise same loss.
    let out2 = rt.execute(&file, &inputs).unwrap();
    assert_eq!(out2[0].to_vec::<f32>().unwrap()[0], loss);
}

#[test]
fn muon_artifact_matches_reference_math() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir(), "tiny").unwrap();
    let mut rt = Runtime::new(&artifacts_dir()).unwrap();
    // Pick a matrix param artifact.
    let p = m.params.iter().find(|p| p.optim == "muon").unwrap();
    let file = m.artifact_file(&p.artifact).unwrap().to_string();
    let dims: Vec<i64> = p.shape.iter().map(|&d| d as i64).collect();

    let mut rng = Rng::new(11);
    let mut w = vec![0.0f32; p.numel];
    let mut g = vec![0.0f32; p.numel];
    rng.fill_normal_f32(&mut w, 0.05);
    rng.fill_normal_f32(&mut g, 1.0);
    let mom = vec![0.0f32; p.numel];
    let lr = 0.02f32;
    let beta = 0.95f32;

    let outs = rt.execute(&file, &[
        literal_f32(&w, &dims).unwrap(),
        literal_f32(&g, &dims).unwrap(),
        literal_f32(&mom, &dims).unwrap(),
        literal_scalar(lr),
        literal_scalar(beta),
    ]).unwrap();
    assert_eq!(outs.len(), 2);
    let w_new = to_f32_vec(&outs[0]).unwrap();
    let mom_new = to_f32_vec(&outs[1]).unwrap();

    // Zero initial momentum => new momentum == gradient exactly.
    assert_eq!(mom_new, g);

    // The weight moved by an (approximately) orthogonal direction with
    // the documented scale: || (w_new - w) / (lr * scale) ||_F^2 ~ min(m,n).
    let (rows, cols) = (p.shape[0] as f32, p.shape[1] as f32);
    let scale = (rows / cols).max(1.0).sqrt();
    let mut frob2 = 0.0f64;
    for i in 0..p.numel {
        let step = (w_new[i] - w[i]) / (lr * scale);
        frob2 += (step as f64) * (step as f64);
    }
    let expect = rows.min(cols) as f64;
    assert!(frob2 > 0.3 * expect && frob2 < 1.8 * expect,
            "||O||_F^2 = {frob2}, expected ~{expect}");
}

#[test]
fn adamw_artifact_matches_reference_math() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir(), "tiny").unwrap();
    let mut rt = Runtime::new(&artifacts_dir()).unwrap();
    let p = m.params.iter().find(|p| p.optim == "adamw").unwrap();
    let file = m.artifact_file(&p.artifact).unwrap().to_string();
    let n = p.numel;
    let dims = [n as i64];

    let mut rng = Rng::new(13);
    let mut w = vec![0.0f32; n];
    let mut g = vec![0.0f32; n];
    rng.fill_normal_f32(&mut w, 1.0);
    rng.fill_normal_f32(&mut g, 1.0);
    let zero = vec![0.0f32; n];
    let (t, lr, b1, b2, eps) = (1.0f32, 3e-3f32, 0.9f32, 0.95f32, 1e-8f32);

    let outs = rt.execute(&file, &[
        literal_f32(&w, &dims).unwrap(),
        literal_f32(&g, &dims).unwrap(),
        literal_f32(&zero, &dims).unwrap(),
        literal_f32(&zero, &dims).unwrap(),
        literal_scalar(t),
        literal_scalar(lr),
    ]).unwrap();
    assert_eq!(outs.len(), 3);
    let w_new = to_f32_vec(&outs[0]).unwrap();
    let m_new = to_f32_vec(&outs[1]).unwrap();
    let v_new = to_f32_vec(&outs[2]).unwrap();

    for i in 0..n {
        let m_ref = (1.0 - b1) * g[i];
        let v_ref = (1.0 - b2) * g[i] * g[i];
        let m_hat = m_ref / (1.0 - b1.powf(t));
        let v_hat = v_ref / (1.0 - b2.powf(t));
        let w_ref = w[i] - lr * m_hat / (v_hat.sqrt() + eps);
        assert!((m_new[i] - m_ref).abs() < 1e-6);
        assert!((v_new[i] - v_ref).abs() < 1e-6);
        assert!((w_new[i] - w_ref).abs() < 1e-5,
                "{} vs {} at {i}", w_new[i], w_ref);
    }
}

#[test]
fn shampoo_artifact_executes() {
    require_artifacts!();
    let m = Manifest::load(&artifacts_dir(), "tiny").unwrap();
    let Some((key, file)) = m.artifacts.iter().find(|(k, _)| k.starts_with("shampoo_")) else {
        eprintln!("skipping: shampoo artifacts not built for tiny");
        return;
    };
    // shampoo_<m>x<n>: (w, g, L[m,m], R[n,n], lr) -> (w', L', R')
    let file = file.clone();
    let dims_str = key.strip_prefix("shampoo_").unwrap();
    let (rows, cols): (usize, usize) = {
        let mut it = dims_str.split('x').map(|d| d.parse().unwrap());
        (it.next().unwrap(), it.next().unwrap())
    };
    let mut rt = Runtime::new(&artifacts_dir()).unwrap();
    let dims = [rows as i64, cols as i64];
    let mut rng = Rng::new(17);
    let mut w = vec![0.0f32; rows * cols];
    let mut g = vec![0.0f32; rows * cols];
    rng.fill_normal_f32(&mut w, 0.1);
    rng.fill_normal_f32(&mut g, 1.0);
    let zeros_l = vec![0.0f32; rows * rows];
    let zeros_r = vec![0.0f32; cols * cols];
    let outs = rt.execute(&file, &[
        literal_f32(&w, &dims).unwrap(),
        literal_f32(&g, &dims).unwrap(),
        literal_f32(&zeros_l, &[rows as i64, rows as i64]).unwrap(),
        literal_f32(&zeros_r, &[cols as i64, cols as i64]).unwrap(),
        literal_scalar(0.05),
    ]).unwrap();
    assert_eq!(outs.len(), 3);
    let w_new = to_f32_vec(&outs[0]).unwrap();
    assert!(w_new.iter().all(|x| x.is_finite()));
    assert_ne!(w_new, w);
    // Statistics L' = (1-beta) G G^T must be symmetric: check a few
    // entries.
    let l_new = to_f32_vec(&outs[1]).unwrap();
    for (i, j) in [(3usize, 7usize), (1, rows - 1), (0, rows / 2)] {
        let a = l_new[i * rows + j];
        let b = l_new[j * rows + i];
        assert!((a - b).abs() < 1e-3 * a.abs().max(1.0), "asymmetry at ({i},{j})");
    }
}
