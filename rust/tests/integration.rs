//! Cross-module integration tests: census -> buffer -> plans -> simulator
//! and the experiment registry end to end (no artifacts required).

use canzona::buffer::FlatBuffer;
use canzona::cost::optim::{CostMetric, OptimCost, OptimKind};
use canzona::model::qwen3::{qwen3, total_params, Qwen3Size};
use canzona::model::tp::{fragmented_matrix_params, tp_split};
use canzona::partition::{alpha_balanced, naive_atomic, DpStrategy};
use canzona::schedule::microgroup::{build_micro_groups, tasks_from_shards};
use canzona::sim::{simulate_iteration, Scenario};
use canzona::util::stats::load_balance_ratio;

#[test]
fn full_pipeline_32b_paper_grid() {
    // The paper's main configuration end to end through the simulator.
    let lb = simulate_iteration(&Scenario::paper_default());
    let nv = simulate_iteration(
        &Scenario::paper_default().with_strategy(DpStrategy::NvLayerwise));
    // Headline shapes (paper: total 1.57x, optimizer 5.8x, fwd-bwd 1.23x).
    let total_speedup = nv.total_s / lb.total_s;
    let opt_speedup = nv.optimizer_s / lb.optimizer_s;
    assert!(total_speedup > 1.2 && total_speedup < 4.0, "{total_speedup}");
    assert!(opt_speedup > 3.0 && opt_speedup < 30.0, "{opt_speedup}");
    assert!(nv.fwd_bwd_s > lb.fwd_bwd_s);
}

#[test]
fn plans_compose_on_every_family_member() {
    for size in Qwen3Size::all() {
        let census = qwen3(size);
        let fb = FlatBuffer::build(&census, 40_000_000);
        for ranks in [2, 8, 32] {
            let plan = alpha_balanced(&fb, ranks, 1.0, true, |p| p.numel() as f64);
            plan.validate(&fb).unwrap();
            let r = load_balance_ratio(&plan.rank_loads(&fb, |p| p.numel() as f64));
            assert!(r < 1.4, "{} R={ranks}: ratio {r}", size.label());
        }
    }
}

#[test]
fn tp_schedule_composes_with_census() {
    let census = qwen3(Qwen3Size::S8B);
    let shards = tp_split(&census, 8);
    let frag = fragmented_matrix_params(&shards, 8);
    let optim = OptimCost::new(OptimKind::Muon);
    let tasks = tasks_from_shards(&frag, &optim, CostMetric::Numel);
    let total_cost: f64 = tasks.iter().map(|t| t.cost).sum();
    let plan = build_micro_groups(tasks, 8, 256e6);
    assert!(plan.is_complete());
    let scheduled: f64 = plan.rank_totals(|t| t.cost).iter().sum();
    assert!((scheduled - total_cost).abs() < 1.0);
    let r = load_balance_ratio(&plan.rank_totals(|t| t.flops));
    assert!(r < 2.0, "TP flops ratio {r}");
}

#[test]
fn simulator_monotone_in_cluster_size() {
    // More DP ranks => less optimizer work per rank (for balanced plans).
    let mut prev = f64::INFINITY;
    for dp in [8, 16, 32, 64] {
        let s = Scenario::new(Qwen3Size::S32B, dp, 8, 1, OptimKind::Muon, DpStrategy::LbAsc);
        let b = simulate_iteration(&s);
        assert!(b.optimizer_s <= prev * 1.05,
                "dp={dp}: {} vs prev {prev}", b.optimizer_s);
        prev = b.optimizer_s;
    }
}

#[test]
fn sc_redundancy_grows_with_nothing() {
    // SC's optimizer time is independent of DP size (fully redundant).
    let t16 = simulate_iteration(
        &Scenario::new(Qwen3Size::S14B, 16, 4, 1, OptimKind::Muon, DpStrategy::Sc));
    let t64 = simulate_iteration(
        &Scenario::new(Qwen3Size::S14B, 64, 4, 1, OptimKind::Muon, DpStrategy::Sc));
    let rel = (t16.optimizer_s - t64.optimizer_s).abs() / t16.optimizer_s;
    assert!(rel < 0.01, "{rel}");
}

#[test]
fn shampoo_and_soap_heavier_than_muon() {
    for opt in [OptimKind::Shampoo, OptimKind::Soap] {
        let muon = simulate_iteration(
            &Scenario::new(Qwen3Size::S14B, 32, 4, 2, OptimKind::Muon, DpStrategy::Sc));
        let other = simulate_iteration(
            &Scenario::new(Qwen3Size::S14B, 32, 4, 2, opt, DpStrategy::Sc));
        assert!(other.optimizer_s > muon.optimizer_s, "{opt:?}");
    }
}

#[test]
fn experiments_all_run() {
    // Every registered harness executes and produces non-empty tables.
    for (id, _) in canzona::experiments::list() {
        let tables = canzona::experiments::run(id).unwrap();
        assert!(!tables.is_empty(), "{id}");
        for t in &tables {
            let rendered = t.render();
            assert!(rendered.lines().filter(|l| l.starts_with('|')).count() >= 3,
                    "{id} produced an empty table");
        }
    }
}

#[test]
fn census_sizes_are_stable() {
    // Guard against accidental census edits: pin totals within 1%.
    let expect = [
        (Qwen3Size::S1_7B, 2.03e9),
        (Qwen3Size::S32B, 33.0e9),
    ];
    for (size, approx) in expect {
        let n = total_params(&qwen3(size)) as f64;
        assert!((n - approx).abs() / approx < 0.05, "{}: {n:.3e}", size.label());
    }
}

#[test]
fn naive_atomic_eq1_owner_rule_holds() {
    // Every parameter's owner interval contains its start index.
    let census = qwen3(Qwen3Size::S4B);
    let fb = FlatBuffer::build(&census, 40_000_000);
    let plan = naive_atomic(&fb, 16);
    plan.validate(&fb).unwrap();
    let stride = fb.total as f64 / 16.0;
    for p in &fb.params {
        let owner = plan.owner_of(p);
        let expect = ((p.start as f64 / stride) as usize).min(15);
        assert_eq!(owner, expect, "{}", p.param.name);
    }
}
