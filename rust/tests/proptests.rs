//! Randomized property tests over the coordinator invariants:
//! partitioning (coverage, atomicity, monotonicity), scheduling
//! (completeness, capacity, determinism) and the collectives' algebra.

use canzona::buffer::FlatBuffer;
use canzona::collectives::{Communicator, Group};
use canzona::model::shapes::{Param, ParamKind, TensorShape};
use canzona::partition::{
    alpha_balanced, equal_chunk, layerwise, naive_atomic, naive_atomic_per_bucket,
};
use canzona::schedule::microgroup::{build_micro_groups, TpTask};
use canzona::schedule::minheap::min_heap_balance;
use canzona::util::prop::check;
use canzona::util::rng::Rng;
use canzona::util::stats::load_balance_ratio;

const CASES: usize = 60;

/// A random census mixing matrix (atomic) and vector/embed (splittable)
/// parameters.
fn random_census(rng: &mut Rng) -> Vec<Param> {
    let n = 3 + rng.index(40);
    (0..n)
        .map(|i| {
            let kind = match rng.index(4) {
                0 => ParamKind::Vector,
                1 => ParamKind::Embed,
                _ => ParamKind::Matrix,
            };
            let shape = match kind {
                ParamKind::Vector => TensorShape::vector(1 + rng.index(4096)),
                _ => TensorShape::matrix(1 + rng.index(256), 1 + rng.index(256)),
            };
            Param::new(&format!("p{i}"), shape, kind, Some(i / 4))
        })
        .collect()
}

struct Case {
    census: Vec<Param>,
    ranks: usize,
    bucket: usize,
    alpha: f64,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Case(ranks={}, bucket={}, alpha={:.2}, {} params)",
               self.ranks, self.bucket, self.alpha, self.census.len())
    }
}

fn random_case(rng: &mut Rng) -> Case {
    Case {
        census: random_census(rng),
        ranks: 1 + rng.index(16),
        bucket: 1 + rng.index(200_000),
        alpha: rng.next_f64(),
    }
}

#[test]
fn prop_alpha_balanced_always_valid() {
    check("alpha_balanced valid", CASES, random_case, |c| {
        let fb = FlatBuffer::build(&c.census, c.bucket);
        for split in [false, true] {
            let plan = alpha_balanced(&fb, c.ranks, c.alpha, split, |p| p.numel() as f64);
            plan.validate(&fb).map_err(|e| format!("{e} (split={split})"))?;
        }
        Ok(())
    });
}

#[test]
fn prop_load_conservation() {
    check("load conservation", CASES, random_case, |c| {
        let fb = FlatBuffer::build(&c.census, c.bucket);
        let total = fb.total as f64;
        for plan in [
            alpha_balanced(&fb, c.ranks, c.alpha, true, |p| p.numel() as f64),
            naive_atomic(&fb, c.ranks),
            equal_chunk(&fb, c.ranks),
        ] {
            let sum: f64 = plan.rank_loads(&fb, |p| p.numel() as f64).iter().sum();
            if (sum - total).abs() > 1.0 {
                return Err(format!("loads sum {sum} != total {total}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_balanced_no_worse_than_naive() {
    check("alpha=1 beats naive makespan", CASES, random_case, |c| {
        let fb = FlatBuffer::build(&c.census, c.bucket);
        let w = |p: &canzona::buffer::PlacedParam| p.numel() as f64;
        let naive = naive_atomic(&fb, c.ranks);
        let bal = alpha_balanced(&fb, c.ranks, 1.0, true, w);
        let max = |loads: Vec<f64>| loads.into_iter().fold(0.0, f64::max);
        let m_naive = max(naive.rank_loads(&fb, w));
        let m_bal = max(bal.rank_loads(&fb, w));
        // Tolerance: per-bucket nearest-boundary rounding can misplace up
        // to one atomic (matrix) parameter per bucket relative to a lucky
        // stride layout — adversarial tiny-bucket censuses hit this.
        let max_atom = fb
            .params
            .iter()
            .filter(|p| p.param.is_matrix_opt())
            .map(|p| p.numel() as f64)
            .fold(0.0, f64::max);
        if m_bal > (m_naive * 1.25 + 1.0).max(m_naive + max_atom) {
            return Err(format!("balanced {m_bal} worse than naive {m_naive}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dp_plans_cover_every_param_exactly_once() {
    // Disjoint + exhaustive ownership: each parameter appears in exactly
    // one rank's list, and (for atomic plans) sits inside its owner's cut
    // interval.
    check("dp plan coverage", CASES, random_case, |c| {
        let fb = FlatBuffer::build(&c.census, c.bucket);
        let plans = [
            ("alpha_balanced", alpha_balanced(&fb, c.ranks, c.alpha, false,
                                              |p| p.numel() as f64)),
            ("naive_atomic", naive_atomic(&fb, c.ranks)),
            ("naive_atomic_per_bucket", naive_atomic_per_bucket(&fb, c.ranks)),
        ];
        for (name, plan) in &plans {
            let mut owners = vec![0usize; fb.params.len()];
            for (r, members) in plan.rank_params(&fb).iter().enumerate() {
                for &pi in members {
                    owners[pi] += 1;
                    let cuts = &plan.cuts[fb.params[pi].bucket];
                    let (lo, hi) = (cuts[r], cuts[r + 1]);
                    let p = &fb.params[pi];
                    // Strict plans: the whole tensor inside the interval.
                    if !(lo <= p.start && p.end <= hi) {
                        return Err(format!(
                            "{name}: param {pi} [{}, {}) outside rank {r} [{lo}, {hi})",
                            p.start, p.end));
                    }
                }
            }
            if let Some(pi) = owners.iter().position(|&n| n != 1) {
                return Err(format!("{name}: param {pi} owned {} times", owners[pi]));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_balanced_ratio_no_worse_than_naive() {
    // The ISSUE-level invariant behind Fig. 3c: the α-balanced Max/Avg
    // load ratio never exceeds naive-atomic's, up to one atomic (matrix)
    // tensor of per-bucket rounding slack on adversarial tiny censuses.
    check("alpha-balanced ratio <= naive", CASES, random_case, |c| {
        let fb = FlatBuffer::build(&c.census, c.bucket);
        let w = |p: &canzona::buffer::PlacedParam| p.numel() as f64;
        let r_naive = load_balance_ratio(&naive_atomic(&fb, c.ranks).rank_loads(&fb, w));
        let r_bal = load_balance_ratio(
            &alpha_balanced(&fb, c.ranks, 1.0, true, w).rank_loads(&fb, w));
        let avg = fb.total as f64 / c.ranks as f64;
        let max_atom = fb
            .params
            .iter()
            .filter(|p| p.param.is_matrix_opt())
            .map(|p| p.numel() as f64)
            .fold(0.0, f64::max);
        let slack = (r_naive * 0.25 + 1.0 / avg.max(1.0)).max(max_atom / avg.max(1.0));
        if r_bal > r_naive + slack + 1e-9 {
            return Err(format!(
                "balanced ratio {r_bal} > naive {r_naive} (+slack {slack})"));
        }
        Ok(())
    });
}

#[test]
fn prop_micro_group_rollback_never_exceeds_c_max() {
    // Tight capacities (barely above the largest task) force the greedy
    // rollback path constantly; every emitted group must still respect
    // C_max, cover every task once, and keep per-group loads consistent.
    check("rollback respects C_max", CASES, |rng| {
        let n = 1 + rng.index(60);
        let tasks: Vec<TpTask> = (0..n)
            .map(|id| {
                let c = 0.5 + rng.next_f64() * 80.0;
                TpTask {
                    id,
                    name: format!("t{id}"),
                    cost: c,
                    comm_bytes: 2.0 * c,
                    flops: 10.0 * c,
                    state_bytes: 4.0 * c,
                }
            })
            .collect();
        let ranks = 1 + rng.index(8);
        let max_cost = tasks.iter().map(|t| t.cost).fold(0.0, f64::max);
        // Within 25% of the single-task lower bound: rollback-heavy.
        let cap = max_cost * (1.0 + rng.next_f64() * 0.25);
        (tasks, ranks, cap)
    }, |(tasks, ranks, cap)| {
        let plan = build_micro_groups(tasks.clone(), *ranks, *cap);
        if !plan.is_complete() {
            return Err("rollback dropped or duplicated a task".into());
        }
        for (gi, g) in plan.groups.iter().enumerate() {
            if g.max_load > cap + 1e-9 {
                return Err(format!("group {gi}: load {} > C_max {cap}", g.max_load));
            }
            let mut loads = vec![0.0f64; *ranks];
            for &(t, r) in &g.assignments {
                loads[r] += plan.tasks[t].cost;
            }
            for (r, (&got, &want)) in loads.iter().zip(&g.rank_loads).enumerate() {
                if (got - want).abs() > 1e-9 {
                    return Err(format!(
                        "group {gi} rank {r}: recomputed load {got} != recorded {want}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_equal_chunk_near_uniform() {
    check("equal chunk shards", CASES, random_case, |c| {
        let fb = FlatBuffer::build(&c.census, c.bucket);
        let plan = equal_chunk(&fb, c.ranks);
        for (i, b) in fb.buckets.iter().enumerate() {
            let sizes = plan.shard_sizes(i);
            let ideal = b.size() / c.ranks;
            for (r, &s) in sizes.iter().enumerate() {
                // all shards == ideal except the last (remainder)
                if r + 1 < c.ranks && s != ideal {
                    return Err(format!("bucket {i} rank {r}: shard {s} vs ideal {ideal}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_layerwise_assigns_layers_atomically() {
    check("layerwise whole layers", CASES, random_case, |c| {
        let fb = FlatBuffer::build(&c.census, c.bucket);
        let plan = layerwise(&fb, c.ranks, |p| p.numel() as f64);
        for l in 0..10 {
            let owners: Vec<usize> = fb
                .params
                .iter()
                .filter(|p| p.param.layer == Some(l))
                .map(|p| plan.owner[p.index])
                .collect();
            if owners.windows(2).any(|w| w[0] != w[1]) {
                return Err(format!("layer {l} split across ranks"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_minheap_within_graham_bound() {
    check("minheap graham", CASES, |rng| {
        let n = 1 + rng.index(60);
        let r = 1 + rng.index(12);
        let costs: Vec<f64> = (0..n).map(|_| 0.5 + rng.next_f64() * 100.0).collect();
        (costs, r)
    }, |(costs, r)| {
        let a = min_heap_balance(costs, *r);
        let total: f64 = costs.iter().sum();
        let max_item = costs.iter().cloned().fold(0.0, f64::max);
        let opt_lb = (total / *r as f64).max(max_item);
        let bound = (4.0 / 3.0 - 1.0 / (3.0 * *r as f64)) * opt_lb + 1e-9;
        if a.max_load > bound {
            return Err(format!("makespan {} > Graham bound {bound}", a.max_load));
        }
        Ok(())
    });
}

#[test]
fn prop_micro_groups_complete_and_capped() {
    check("micro groups", CASES, |rng| {
        let n = 1 + rng.index(50);
        let tasks: Vec<TpTask> = (0..n)
            .map(|id| {
                let c = 1.0 + rng.next_f64() * 50.0;
                TpTask {
                    id,
                    name: format!("t{id}"),
                    cost: c,
                    comm_bytes: 2.0 * c,
                    flops: 10.0 * c,
                    state_bytes: 4.0 * c,
                }
            })
            .collect();
        let ranks = 1 + rng.index(8);
        // Capacity always >= the largest single task.
        let cap = tasks.iter().map(|t| t.cost).fold(0.0, f64::max)
            * (1.0 + rng.next_f64() * 3.0);
        (tasks, ranks, cap)
    }, |(tasks, ranks, cap)| {
        let plan = build_micro_groups(tasks.clone(), *ranks, *cap);
        if !plan.is_complete() {
            return Err("plan not complete".into());
        }
        for (gi, g) in plan.groups.iter().enumerate() {
            if g.max_load > cap + 1e-9 {
                return Err(format!("group {gi} load {} > cap {cap}", g.max_load));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_collectives_algebra() {
    // RS_v followed by AG_v reconstructs the rank-ordered sum, for random
    // sizes; and AR equals that sum bitwise.
    check("rs+ag == ar", 20, |rng| {
        let ranks = 2 + rng.index(6);
        let n = 1 + rng.index(500);
        let mut sizes = vec![0usize; ranks];
        for _ in 0..n {
            let r = rng.index(ranks);
            sizes[r] += 1;
        }
        (ranks, sizes, n, rng.next_u64())
    }, |(ranks, sizes, n, seed)| {
        let group = Group::new(*ranks);
        let handles: Vec<_> = (0..*ranks)
            .map(|r| {
                let comm = Communicator::new(group.clone(), r);
                let sizes = sizes.clone();
                let (n, seed) = (*n, *seed);
                std::thread::spawn(move || {
                    let mut rng = Rng::new(seed ^ r as u64);
                    let data: Vec<f32> = (0..n).map(|_| rng.next_f32() - 0.5).collect();
                    let ar = comm.all_reduce(&data);
                    let shard = comm.reduce_scatter_v(&data, &sizes);
                    let ag = comm.all_gather_v(&shard, &sizes);
                    (ar, ag)
                })
            })
            .collect();
        for h in handles {
            let (ar, ag) = h.join().unwrap();
            if ar != ag {
                return Err("rs+ag != ar (bitwise)".into());
            }
        }
        Ok(())
    });
}
