//! Precision-verification tests (paper Fig. 5): the distributed
//! strategies are *purely system-level* — SC, ASC and LB-ASC must yield
//! bitwise-identical training trajectories.
//!
//! Requires `make artifacts` (tiny preset); skips otherwise.

use std::path::PathBuf;

use canzona::partition::DpStrategy;
use canzona::train::{train, TrainConfig};

fn artifacts_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifacts_dir().join("manifest__tiny.json").exists()
}

fn cfg(strategy: DpStrategy, ranks: usize, steps: usize) -> TrainConfig {
    let mut c = TrainConfig::new("tiny");
    c.artifacts_dir = artifacts_dir();
    c.ranks = ranks;
    c.steps = steps;
    c.strategy = strategy;
    c.log_every = 0;
    c.bucket_elems = 30_000; // several buckets on the tiny census
    c
}

macro_rules! require_artifacts {
    () => {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

#[test]
fn fig5_parity_sc_vs_lb_asc_bitwise() {
    require_artifacts!();
    let sc = train(&cfg(DpStrategy::Sc, 4, 6)).unwrap();
    let lb = train(&cfg(DpStrategy::LbAsc, 4, 6)).unwrap();
    assert_eq!(sc.losses, lb.losses, "loss curves diverged");
    assert_eq!(sc.params_hash, lb.params_hash, "final parameters diverged");
}

#[test]
fn fig5_parity_asc_bitwise() {
    require_artifacts!();
    let sc = train(&cfg(DpStrategy::Sc, 4, 4)).unwrap();
    let asc = train(&cfg(DpStrategy::Asc, 4, 4)).unwrap();
    assert_eq!(sc.losses, asc.losses);
    assert_eq!(sc.params_hash, asc.params_hash);
}

#[test]
fn parity_across_rank_counts_is_not_required_but_losses_decrease() {
    require_artifacts!();
    // Different DP sizes see different data (per-rank batches), so no
    // bitwise parity — but training must make progress on both.
    let r2 = train(&cfg(DpStrategy::LbAsc, 2, 12)).unwrap();
    let r4 = train(&cfg(DpStrategy::LbAsc, 4, 12)).unwrap();
    for r in [&r2, &r4] {
        let first = r.losses.first().unwrap();
        let last = r.losses.last().unwrap();
        assert!(last < first, "loss did not decrease: {first} -> {last}");
    }
}

#[test]
fn alpha_variants_keep_parity() {
    require_artifacts!();
    // Any α yields a different partition but identical math.
    let a0 = train(&{ let mut c = cfg(DpStrategy::LbAsc, 4, 4); c.alpha = 0.0; c }).unwrap();
    let a1 = train(&{ let mut c = cfg(DpStrategy::LbAsc, 4, 4); c.alpha = 1.0; c }).unwrap();
    assert_eq!(a0.losses, a1.losses);
    assert_eq!(a0.params_hash, a1.params_hash);
}

#[test]
fn comm_volume_sc_not_lower_than_lb_asc() {
    require_artifacts!();
    // SC = All-Reduce (2x RS volume) but no All-Gather; LB-ASC = RS + AG.
    // Volumes match in total; neither should exceed the other by >1%.
    let sc = train(&cfg(DpStrategy::Sc, 4, 4)).unwrap();
    let lb = train(&cfg(DpStrategy::LbAsc, 4, 4)).unwrap();
    let rel = (sc.comm_bytes as f64 - lb.comm_bytes as f64).abs()
        / lb.comm_bytes as f64;
    assert!(rel < 0.01, "sc {} vs lb {}", sc.comm_bytes, lb.comm_bytes);
}

#[test]
fn single_rank_matches_multi_rank_when_data_matches() {
    require_artifacts!();
    // ranks=1 LB-ASC == ranks=1 SC (degenerate case sanity).
    let sc = train(&cfg(DpStrategy::Sc, 1, 4)).unwrap();
    let lb = train(&cfg(DpStrategy::LbAsc, 1, 4)).unwrap();
    assert_eq!(sc.losses, lb.losses);
    assert_eq!(sc.params_hash, lb.params_hash);
}
