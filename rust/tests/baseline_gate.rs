//! End-to-end `canzona sweep --baseline` regression gate, through the
//! real CLI entry point: a clean self-diff exits zero; an injected
//! regression fixture exits nonzero (run_cli returns Err, which main
//! maps to a nonzero process exit).

use std::fs;
use std::path::PathBuf;

use canzona::coordinator::run_cli;
use canzona::util::json::Value;

fn tmp_path(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("canzona_baseline_gate_{}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(|x| x.to_string()).collect()
}

const GRID: &str = "--models 1.7b --dp 4 --tp 2 --pp 1 --strategies asc,lb-asc";

#[test]
fn baseline_gate_round_trip() {
    let base = tmp_path("base.json");
    let base_s = base.to_str().unwrap();

    // Capture a baseline artifact.
    run_cli(argv(&format!("sweep {GRID} --threads 2 --json {base_s}"))).unwrap();
    let artifact = Value::parse(&fs::read_to_string(&base).unwrap()).unwrap();
    assert!(artifact.get("cache").is_ok(), "artifact must carry cache stats");
    assert_eq!(artifact.get("scenarios").unwrap().as_arr().unwrap().len(), 2);

    // Clean self-diff: identical code, deterministic model => exit 0
    // even at a 0% threshold.
    run_cli(argv(&format!(
        "sweep {GRID} --threads 2 --baseline {base_s} --regress-pct 0"
    )))
    .unwrap();

    // Injected regression fixture: pretend the baseline was 25% faster.
    let mut tampered = artifact.clone();
    if let Value::Obj(m) = &mut tampered {
        let Some(Value::Arr(rows)) = m.get_mut("scenarios") else { panic!() };
        for row in rows.iter_mut() {
            let Value::Obj(r) = row else { panic!() };
            let t = r.get("total_s").unwrap().as_f64().unwrap();
            r.insert("total_s".into(), Value::num(t * 0.75));
        }
    }
    let bad = tmp_path("base_regressed.json");
    fs::write(&bad, tampered.to_string()).unwrap();
    let err = run_cli(argv(&format!(
        "sweep {GRID} --threads 2 --baseline {}",
        bad.to_str().unwrap()
    )))
    .unwrap_err();
    assert!(err.to_string().contains("regression"), "{err}");

    // A corrupt baseline fails loudly, not silently.
    let garbage = tmp_path("garbage.json");
    fs::write(&garbage, "{not json").unwrap();
    assert!(run_cli(argv(&format!(
        "sweep {GRID} --threads 2 --baseline {}",
        garbage.to_str().unwrap()
    )))
    .is_err());
}

#[test]
fn cache_budget_flag_is_accepted() {
    // Tiny budget: must still complete and report eviction counters.
    run_cli(argv(&format!(
        "sweep {GRID} --threads 2 --cache-budget-mb 0.05"
    )))
    .unwrap();
    // 0 = unbounded.
    run_cli(argv(&format!("sweep {GRID} --threads 1 --cache-budget-mb 0"))).unwrap();
}
