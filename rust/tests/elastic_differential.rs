//! Differential oracles for the elastic-cluster fault model (PR 10):
//!
//! * the legacy `--straggler f` knob and the fault layer's `last:f`
//!   heterogeneity spec are the *same* arithmetic — bit-for-bit equal
//!   Breakdowns across pp, strategies, and factors;
//! * inert fault knobs (`--fault-seed`, `--ckpt-interval` without an
//!   event) leave fault-free results bit-identical — the PR's
//!   "homogeneous default reproduces pre-fault artifacts" contract at
//!   unit level;
//! * the same `--fault-seed` reproduces sweep artifacts byte-for-byte,
//!   parallel evaluation matches serial, and the batch-tier toggle is
//!   invisible on grids that mix fault-free (batched) and faulted
//!   (scalar-fallback) lanes;
//! * an injected rank failure / MTTF rate strictly increases
//!   `recovery_s` and `total_s`, and sparser checkpoints strictly
//!   increase the recovery charge.

mod common;

use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{
    simulate_iteration_cached, FailSpec, HeteroSpec, PipelineSchedule, Scenario,
};
use canzona::sweep::{render_json, render_table, PlanCache, SweepEngine, SweepGrid};

use common::assert_bits_eq;

/// A grid mixing fault-free lanes (which take the batch tier) with
/// heterogeneous, failing, and MTTF-rated lanes (scalar fallback).
fn faulted_grid() -> SweepGrid {
    SweepGrid {
        models: vec![Qwen3Size::S1_7B],
        dp: vec![4],
        tp: vec![2],
        pp: vec![1, 2],
        micro_batches: vec![1, 2],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::LbAsc, DpStrategy::MatrixFsdp, DpStrategy::DMuon],
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0)],
        heteros: vec![
            HeteroSpec::None,
            HeteroSpec::parse("slow:0.5:2+link:0.5:8").unwrap(),
        ],
        fail_ranks: vec![None, Some(FailSpec { rank: 1, at: 0.25 })],
        mttfs: vec![None, Some(1800.0)],
        ckpt_intervals: vec![1, 4],
        metric: CostMetric::Numel,
        fault_seed: 7,
    }
}

#[test]
fn last_stage_hetero_is_bit_identical_to_the_straggler_knob() {
    // `--straggler f` derates the last stage's hardware by `f`; so does
    // `--hetero last:f`. Both route to the timeline arm, where the
    // derate factors multiply (`f * 1.0 == 1.0 * f`), so the two
    // spellings must agree on every output bit.
    let cache = PlanCache::new();
    for &(pp, mb) in &[(1usize, 1usize), (4, 4)] {
        for &strat in &[
            DpStrategy::Asc,
            DpStrategy::LbAsc,
            DpStrategy::MatrixFsdp,
            DpStrategy::DMuon,
        ] {
            for &f in &[1.5f64, 2.0] {
                let base = Scenario::new(Qwen3Size::S1_7B, 4, 2, pp, OptimKind::Muon, strat)
                    .with_micro_batches(mb);
                let straggled = base.clone().with_straggler(f);
                let spec = HeteroSpec::parse(&format!("last:{f}")).unwrap();
                let hetero = base.with_hetero(spec);
                let a = simulate_iteration_cached(&straggled, &cache);
                let b = simulate_iteration_cached(&hetero, &cache);
                assert_bits_eq(&format!("pp{pp} {strat:?} f={f}"), &a, &b);
            }
        }
    }
}

#[test]
fn inert_fault_knobs_leave_clean_results_bit_identical() {
    // `--fault-seed` only salts the profile derivation and
    // `--ckpt-interval` only scales an event's recovery charge: with no
    // heterogeneity and no event, both are inert and the scenario still
    // takes the closed-form arm — pre-fault artifacts reproduce exactly.
    let cache = PlanCache::new();
    for &strat in DpStrategy::ALL.iter() {
        let clean = Scenario::new(Qwen3Size::S1_7B, 8, 2, 1, OptimKind::Muon, strat);
        let knobbed = clean.clone().with_fault_seed(123).with_ckpt_interval(8);
        assert!(!knobbed.faulted(), "seed/ckpt alone must not count as a fault");
        let a = simulate_iteration_cached(&clean, &cache);
        let b = simulate_iteration_cached(&knobbed, &cache);
        assert_bits_eq(&format!("{strat:?}"), &a, &b);
        assert_eq!(a.recovery_s.to_bits(), 0.0f64.to_bits());
    }
}

#[test]
fn same_fault_seed_reproduces_artifacts_byte_for_byte() {
    let grid = faulted_grid();
    let (s1, b1) = SweepEngine::new(2).run_grid(&grid);
    let (s2, b2) = SweepEngine::new(2).run_grid(&grid);
    assert_eq!(
        render_json(&s1, &b1).to_string(),
        render_json(&s2, &b2).to_string(),
        "same seed, same grid: JSON artifacts must be byte-identical",
    );
    assert_eq!(
        render_table(&s1, &b1).render(),
        render_table(&s2, &b2).render(),
        "same seed, same grid: tables must be byte-identical",
    );
}

#[test]
fn parallel_and_serial_sweeps_agree_under_faults() {
    let grid = faulted_grid();
    let (ss, bs) = SweepEngine::new(1).run_grid(&grid);
    let (sp, bp) = SweepEngine::new(4).run_grid(&grid);
    assert_eq!(
        render_json(&ss, &bs).to_string(),
        render_json(&sp, &bp).to_string(),
        "thread count changed faulted sweep artifacts",
    );
}

#[test]
fn batching_toggle_is_invisible_on_faulted_grids() {
    // Faulted lanes take the scalar fallback inside the batch tier
    // (`ScenarioBatch::new` refuses them); fault-free lanes batch. The
    // artifact bytes must not depend on the toggle either way.
    let grid = faulted_grid();
    let on = SweepEngine::new(2);
    let mut off = SweepEngine::new(2);
    off.set_batching(false);
    let (s_on, b_on) = on.run_grid(&grid);
    let (s_off, b_off) = off.run_grid(&grid);
    assert_eq!(
        render_json(&s_on, &b_on).to_string(),
        render_json(&s_off, &b_off).to_string(),
        "--no-batch changed faulted sweep artifacts",
    );
    assert_eq!(off.cache_stats().batched_evals, 0, "--no-batch must not batch");
}

#[test]
fn injected_failures_strictly_increase_recovery_and_total() {
    let cache = PlanCache::new();
    for &strat in DpStrategy::ALL.iter() {
        let clean = Scenario::new(Qwen3Size::S1_7B, 8, 2, 1, OptimKind::Muon, strat);
        let a = simulate_iteration_cached(&clean, &cache);
        assert_eq!(a.recovery_s, 0.0, "{strat:?}: clean scenarios charge no recovery");

        let failed = clean.clone().with_fail_rank(Some(FailSpec { rank: 3, at: 0.5 }));
        let b = simulate_iteration_cached(&failed, &cache);
        assert!(b.recovery_s > 0.0, "{strat:?}: a failure must charge recovery");
        assert!(
            b.total_s > a.total_s,
            "{strat:?}: failure total {} must exceed clean {}",
            b.total_s,
            a.total_s,
        );

        let rated = clean.clone().with_mttf(Some(600.0));
        let c = simulate_iteration_cached(&rated, &cache);
        assert!(c.recovery_s > 0.0, "{strat:?}: an MTTF rate must charge recovery");
        assert!(c.total_s > a.total_s, "{strat:?}");

        // Sparser checkpoints mean more redone work per event.
        let k1 = rated.clone().with_ckpt_interval(1);
        let k8 = rated.with_ckpt_interval(8);
        let r1 = simulate_iteration_cached(&k1, &cache);
        let r8 = simulate_iteration_cached(&k8, &cache);
        assert!(
            r8.recovery_s > r1.recovery_s,
            "{strat:?}: ckpt 8 recovery {} must exceed ckpt 1 {}",
            r8.recovery_s,
            r1.recovery_s,
        );
    }
}

#[test]
fn failure_recovery_holds_on_pipelined_scenarios() {
    // The fault block lives in the timeline arm's tail; make sure a
    // pp > 1, micro-batched schedule charges it too.
    let cache = PlanCache::new();
    let clean = Scenario::new(Qwen3Size::S1_7B, 4, 2, 2, OptimKind::Muon, DpStrategy::LbAsc)
        .with_micro_batches(4);
    let failed = clean.clone().with_fail_rank(Some(FailSpec { rank: 2, at: 0.75 }));
    let a = simulate_iteration_cached(&clean, &cache);
    let b = simulate_iteration_cached(&failed, &cache);
    assert_eq!(a.recovery_s, 0.0);
    assert!(b.recovery_s > 0.0);
    assert!(b.total_s > a.total_s);
}
