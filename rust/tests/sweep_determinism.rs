//! Sweep-engine determinism and plan-cache correctness across the stack:
//!
//! * the parallel work-stealing runner produces byte-identical tables to
//!   a forced single-thread run;
//! * a warm plan cache returns plans structurally equal to cold-path
//!   solves and never re-runs an LPT solve (asserted via the cache's
//!   statistics counters);
//! * `experiments::run("all")` on the shared engine is render-stable.

mod common;

use canzona::buffer::FlatBuffer;
use canzona::cost::optim::OptimKind;
use canzona::model::qwen3::{qwen3, Qwen3Size};
use canzona::partition::{alpha_balanced, DpStrategy};
use canzona::sim::{simulate_iteration_cached, Scenario};
use canzona::sweep::{render_json, render_table, DpKey, PlanCache, SweepEngine};
use common::{pp_grid, test_grid};

#[test]
fn parallel_sweep_is_byte_identical_to_single_thread() {
    let grid = test_grid();
    let serial = SweepEngine::new(1);
    let (scens_s, res_s) = serial.run_grid(&grid);
    for threads in [2, 4, 8, 16] {
        let parallel = SweepEngine::new(threads);
        let (scens_p, res_p) = parallel.run_grid(&grid);
        assert_eq!(
            render_table(&scens_s, &res_s).render(),
            render_table(&scens_p, &res_p).render(),
            "tables diverged at {threads} threads",
        );
        assert_eq!(
            render_json(&scens_s, &res_s).to_string(),
            render_json(&scens_p, &res_p).to_string(),
            "json diverged at {threads} threads",
        );
    }
}

#[test]
fn cached_plans_structurally_equal_cold_solves() {
    // Warm a cache through the simulator, then pull the DP plan it stored
    // and compare it cut-for-cut against a direct cold solve.
    let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc);
    let cache = PlanCache::unbounded();
    simulate_iteration_cached(&s, &cache);

    let key = DpKey::for_scenario(&s, 0);
    let warm = cache.dp_plan(&key, || panic!("plan must already be cached"));

    // Cold path: rebuild the stage-0 buffer exactly as the simulator does
    // (pp=1 → the stage census is the full census, TP-local shapes).
    let locals = canzona::model::tp::tp_split(&qwen3(Qwen3Size::S1_7B), s.tp);
    let local_census: Vec<_> = locals
        .iter()
        .map(|sh| {
            let mut p = sh.param.clone();
            p.shape = sh.shard_shape.clone();
            p
        })
        .collect();
    let fb = FlatBuffer::build(&local_census, s.bucket_elems);
    let cold = alpha_balanced(&fb, s.dp, s.alpha, true, |p| {
        if p.param.is_matrix_opt() {
            locals[p.index].param.numel() as f64
        } else {
            p.param.numel() as f64
        }
    });
    assert_eq!(warm.ranks, cold.ranks);
    assert_eq!(warm.atomicity, cold.atomicity);
    assert_eq!(warm.cuts, cold.cuts, "cached plan != cold solve");
    cold.validate(&fb).unwrap();
}

#[test]
fn repeated_scenario_skips_lpt_solves() {
    // Unbounded: an env budget override must not evict between passes.
    let engine = SweepEngine::with_budget(4, 0);
    let grid = test_grid();
    let (scens, first) = engine.run_grid(&grid);
    let after_cold = engine.cache_stats();
    assert!(after_cold.solves > 0, "cold run must solve plans");

    let second = engine.eval(&scens);
    let after_warm = engine.cache_stats();
    assert_eq!(
        after_warm.solves, after_cold.solves,
        "warm run re-ran an LPT solve",
    );
    // The warm path reads one hoisted stage table per (scenario, stage)
    // plus one TP plan per DP rank; the DP/layerwise solves are folded
    // into the stage-table build, so warm hits are fewer than cold
    // solves — but every scenario must hit at least its stage table.
    assert!(
        after_warm.hits >= after_cold.hits + scens.len() as u64,
        "warm run should hit every scenario's stage table: \
         {after_warm:?} vs {after_cold:?}",
    );
    assert_eq!(after_warm.evictions, 0, "unbounded cache must not evict");
    assert_eq!(
        render_table(&scens, &first).render(),
        render_table(&scens, &second).render(),
        "cache warmth changed results",
    );
}

#[test]
fn run_all_is_render_stable_and_cache_warm() {
    // Two passes over every harness through the shared global engine:
    // identical bytes, and the second pass adds no plan solves.
    let first: Vec<String> = canzona::experiments::run("all")
        .unwrap()
        .iter()
        .map(|t| t.render())
        .collect();
    let solves_after_first = SweepEngine::global().cache_stats().solves;
    let second: Vec<String> = canzona::experiments::run("all")
        .unwrap()
        .iter()
        .map(|t| t.render())
        .collect();
    assert_eq!(first.len(), second.len());
    for (a, b) in first.iter().zip(&second) {
        // The planning-latency table reports wall time; skip it.
        if a.contains("Offline planning latency") {
            continue;
        }
        assert_eq!(a, b);
    }
    assert_eq!(
        SweepEngine::global().cache_stats().solves,
        solves_after_first,
        "second run(\"all\") re-solved plans",
    );
}

#[test]
fn pp_sweep_parallel_is_byte_identical_to_single_thread() {
    // The timeline engine is pure arithmetic over cached tables, so the
    // pp>1 path must be exactly as scheduler-independent as pp=1.
    let grid = pp_grid();
    let serial = SweepEngine::new(1);
    let (scens_s, res_s) = serial.run_grid(&grid);
    let parallel = SweepEngine::new(8);
    let (scens_p, res_p) = parallel.run_grid(&grid);
    assert_eq!(
        render_table(&scens_s, &res_s).render(),
        render_table(&scens_p, &res_p).render(),
        "pp>1 tables diverged across thread counts",
    );
    assert_eq!(
        render_json(&scens_s, &res_s).to_string(),
        render_json(&scens_p, &res_p).to_string(),
        "pp>1 json diverged across thread counts",
    );
}

#[test]
fn pp_sweep_warm_cache_skips_solves_and_preserves_bytes() {
    // cached == cold through the timeline engine: a second pass over the
    // pp grid adds no plan solves and renders identical bytes.
    let engine = SweepEngine::with_budget(4, 0);
    let grid = pp_grid();
    let (scens, first) = engine.run_grid(&grid);
    let cold = engine.cache_stats();
    assert!(cold.solves > 0);
    let second = engine.eval(&scens);
    let warm = engine.cache_stats();
    assert_eq!(warm.solves, cold.solves, "pp>1 warm run re-ran a solve");
    assert_eq!(warm.evictions, 0);
    assert_eq!(
        render_table(&scens, &first).render(),
        render_table(&scens, &second).render(),
        "cache warmth changed pp>1 results",
    );
}

#[test]
fn interior_stages_share_cached_tables() {
    // Stage canonicalization: a pp=8 scenario has 8 stages but only 3
    // distinct censuses (embed stage, interior, head stage) — the cache
    // must solve 3 stage tables, not 8.
    let mut s = Scenario::new(Qwen3Size::S1_7B, 4, 1, 8, OptimKind::Muon, DpStrategy::LbAsc);
    s.micro_batches = 2;
    let cache = PlanCache::unbounded();
    simulate_iteration_cached(&s, &cache);
    // tp=1, LB-ASC: one DP plan + one stage table per *distinct* stage.
    assert_eq!(cache.len(), 6, "expected 3 stage tables + 3 DP plans");
    let warm_solves = cache.stats().solves;
    simulate_iteration_cached(&s, &cache);
    assert_eq!(cache.stats().solves, warm_solves, "warm pp=8 run re-solved");
}

#[test]
fn repeated_batches_on_persistent_workers_are_byte_stable() {
    // The persistent executor reuses worker threads (and their
    // SimScratch / cache-L1 state) across eval calls; interleaving two
    // different grids over many batches must leave every batch's bytes
    // identical to its first run — warm per-worker state is a pure
    // throughput optimization.
    let engine = SweepEngine::with_budget(4, 0);
    let plain = test_grid().scenarios();
    let piped = pp_grid().scenarios();
    let first_plain = render_table(&plain, &engine.eval(&plain)).render();
    let first_piped = render_table(&piped, &engine.eval(&piped)).render();
    for round in 0..3 {
        assert_eq!(
            render_table(&plain, &engine.eval(&plain)).render(),
            first_plain,
            "plain grid drifted on round {round}",
        );
        assert_eq!(
            render_table(&piped, &engine.eval(&piped)).render(),
            first_piped,
            "pp grid drifted on round {round}",
        );
    }
}

#[test]
fn thread_env_does_not_change_results() {
    // The runner must be a pure throughput knob: evaluate the same batch
    // under wildly different worker counts, bit-compare everything the
    // sweep table does not even show.
    let scens = test_grid().scenarios();
    let a = SweepEngine::new(1).eval(&scens);
    let b = SweepEngine::new(16).eval(&scens);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.fwd_bwd_s.to_bits(), y.fwd_bwd_s.to_bits());
        assert_eq!(x.optimizer_s.to_bits(), y.optimizer_s.to_bits());
        assert_eq!(x.exposed_comm_s.to_bits(), y.exposed_comm_s.to_bits());
        assert_eq!(x.grad_comm_bytes.to_bits(), y.grad_comm_bytes.to_bits());
        let bits = |v: &[f64]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&x.dp_loads_flops), bits(&y.dp_loads_flops));
        assert_eq!(bits(&x.dp_loads_state), bits(&y.dp_loads_state));
        assert_eq!(bits(&x.tp_loads_flops), bits(&y.tp_loads_flops));
        assert_eq!(x.n_micro_groups, y.n_micro_groups);
    }
}
