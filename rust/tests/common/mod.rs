//! Shared test support: the scenario/grid builders and Breakdown
//! comparators previously copy-pasted across the integration suites
//! (`sweep_determinism.rs`, `timeline_differential.rs`,
//! `optimize_differential.rs`, `batch_differential.rs`). Each suite
//! pulls this in with `mod common;` — keep everything here suite-
//! agnostic (no `#[test]`s, no suite-specific constants).

// Each integration-test binary compiles its own copy of this module and
// typically uses a subset of it.
#![allow(dead_code)]

use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{Breakdown, HeteroSpec, PipelineSchedule};
use canzona::sweep::SweepGrid;

/// Relative-or-absolute closeness: timings are ~1e-3..1e1 s, so 1e-9
/// relative; the absolute floor absorbs exact-zero fields (bubble at
/// full overlap) where two derivations differ only in summation order.
pub fn close(a: f64, b: f64) -> bool {
    (a - b).abs() <= 1e-9 * a.abs().max(b.abs()) + 1e-12
}

/// Assert two breakdowns agree within [`close`] on every timing field,
/// and exactly on the load vectors / plan statistics (which come from
/// the same cached tables on both paths).
pub fn assert_breakdowns_match(label: &str, closed: &Breakdown, event: &Breakdown) {
    for (field, a, b) in [
        ("fwd_bwd_s", closed.fwd_bwd_s, event.fwd_bwd_s),
        ("optimizer_s", closed.optimizer_s, event.optimizer_s),
        ("total_s", closed.total_s, event.total_s),
        ("exposed_comm_s", closed.exposed_comm_s, event.exposed_comm_s),
        ("bubble_s", closed.bubble_s, event.bubble_s),
        ("adamw_ref_s", closed.adamw_ref_s, event.adamw_ref_s),
        ("grad_comm_bytes", closed.grad_comm_bytes, event.grad_comm_bytes),
        ("recovery_s", closed.recovery_s, event.recovery_s),
    ] {
        assert!(
            close(a, b),
            "{label}: {field} diverged: closed={a:.17e} event={b:.17e} \
             (rel {:.3e})",
            (a - b).abs() / a.abs().max(b.abs()).max(1e-300),
        );
    }
    assert_eq!(closed.n_micro_groups, event.n_micro_groups, "{label}");
    assert_eq!(closed.dp_loads_flops, event.dp_loads_flops, "{label}");
    assert_eq!(closed.dp_loads_state, event.dp_loads_state, "{label}");
    assert_eq!(closed.tp_loads_flops, event.tp_loads_flops, "{label}");
    assert_eq!(closed.tp_loads_state, event.tp_loads_state, "{label}");
}

/// Bit-level Breakdown equality over every field except `planning_s`
/// (wall-clock cache-fetch latency — not a simulation output, so it is
/// the one field no differential oracle can pin).
pub fn assert_bits_eq(label: &str, a: &Breakdown, b: &Breakdown) {
    for (field, x, y) in [
        ("fwd_bwd_s", a.fwd_bwd_s, b.fwd_bwd_s),
        ("optimizer_s", a.optimizer_s, b.optimizer_s),
        ("total_s", a.total_s, b.total_s),
        ("adamw_ref_s", a.adamw_ref_s, b.adamw_ref_s),
        ("exposed_comm_s", a.exposed_comm_s, b.exposed_comm_s),
        ("grad_comm_bytes", a.grad_comm_bytes, b.grad_comm_bytes),
        ("bubble_s", a.bubble_s, b.bubble_s),
        ("recovery_s", a.recovery_s, b.recovery_s),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{label}: {field} {x} vs {y}");
    }
    for (field, xs, ys) in [
        ("dp_loads_flops", &a.dp_loads_flops, &b.dp_loads_flops),
        ("dp_loads_state", &a.dp_loads_state, &b.dp_loads_state),
        ("tp_loads_flops", &a.tp_loads_flops, &b.tp_loads_flops),
        ("tp_loads_state", &a.tp_loads_state, &b.tp_loads_state),
    ] {
        assert_eq!(xs.len(), ys.len(), "{label}: {field} length");
        for (i, (x, y)) in xs.iter().zip(ys.iter()).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{label}: {field}[{i}] {x} vs {y}");
        }
    }
    assert_eq!(a.n_micro_groups, b.n_micro_groups, "{label}: n_micro_groups");
}

/// Small two-model sweep grid exercising the closed-form path (pp = 1)
/// across DP strategies — `sweep_determinism.rs`'s workhorse.
pub fn test_grid() -> SweepGrid {
    SweepGrid {
        models: vec![Qwen3Size::S1_7B, Qwen3Size::S4B],
        dp: vec![8],
        tp: vec![2, 4],
        pp: vec![1],
        micro_batches: vec![1],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![
            DpStrategy::Asc,
            DpStrategy::LbAsc,
            DpStrategy::MatrixFsdp,
            DpStrategy::DMuon,
            DpStrategy::Dion,
        ],
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0)],
        heteros: vec![HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    }
}

/// A pp>1 grid exercising the timeline engine through the sweep stack
/// (schedules × stragglers × micro-batches).
pub fn pp_grid() -> SweepGrid {
    SweepGrid {
        models: vec![Qwen3Size::S1_7B],
        dp: vec![4],
        tp: vec![2],
        pp: vec![1, 2, 4],
        micro_batches: vec![1, 4],
        schedules: vec![PipelineSchedule::OneFOneB, PipelineSchedule::GPipe],
        stragglers: vec![1.0, 1.5],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::Asc, DpStrategy::LbAsc, DpStrategy::MatrixFsdp],
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0)],
        heteros: vec![HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    }
}

/// Every strategy × optimizer × size × TP × fusion at pp = 1 — the
/// differential oracles' coverage grid. Spans the full strategy zoo
/// (`DpStrategy::ALL`): the ladder plus MatrixFSDP / DMuon / Dion, so
/// no strategy arm can land without passing the timeline, batch, and
/// optimize oracles.
pub fn oracle_grid() -> SweepGrid {
    SweepGrid {
        models: vec![Qwen3Size::S1_7B, Qwen3Size::S4B],
        dp: vec![8],
        tp: vec![1, 4],
        pp: vec![1],
        micro_batches: vec![1],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon, OptimKind::Shampoo, OptimKind::Soap, OptimKind::AdamW],
        strategies: DpStrategy::ALL.to_vec(),
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0), None],
        heteros: vec![HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    }
}

/// A 1-point Qwen3-1.7B grid tests override axes on (struct-update
/// syntax) — `optimize_differential.rs`'s base.
pub fn base_grid() -> SweepGrid {
    SweepGrid {
        models: vec![Qwen3Size::S1_7B],
        dp: vec![4],
        tp: vec![2],
        pp: vec![1],
        micro_batches: vec![1],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::LbAsc],
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0)],
        heteros: vec![HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    }
}
