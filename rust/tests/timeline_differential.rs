//! Differential oracle: the event-driven timeline engine must reproduce
//! the closed-form `simulate_iteration` Breakdown at `pp = 1,
//! micro_batches = 1` for every strategy × optimizer × size × TP ×
//! fusion setting in the test sweep grid, within 1e-9 relative
//! tolerance — the two paths are independent derivations of the same
//! schedule, so agreement here is the engine's correctness anchor.
//!
//! Also pins the dispatch rule: `pp > 1` / `micro_batches > 1` /
//! `straggler != 1.0` scenarios evaluated through the public
//! `simulate_iteration*` entry points are bit-identical to calling the
//! timeline engine directly.

mod common;

use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::partition::DpStrategy;
use canzona::sim::{
    simulate_iteration_cached, simulate_iteration_timeline, PipelineSchedule, Scenario,
};
use canzona::sweep::{PlanCache, SweepGrid};
use common::{assert_breakdowns_match, oracle_grid};

#[test]
fn timeline_reproduces_closed_form_at_pp1() {
    let cache = PlanCache::unbounded();
    for s in oracle_grid().scenarios() {
        let label = format!(
            "{} tp{} {} {} c_max={:?}",
            s.label,
            s.tp,
            s.optim.label(),
            s.strategy.label(),
            s.c_max_bytes,
        );
        let closed = simulate_iteration_cached(&s, &cache); // pp=1 fast path
        let event = simulate_iteration_timeline(&s, &cache);
        assert_breakdowns_match(&label, &closed, &event);
    }
}

#[test]
fn timeline_agrees_warm_and_cold() {
    // A warm cache must not change the event engine's timings.
    let s = Scenario::new(Qwen3Size::S1_7B, 8, 4, 1, OptimKind::Muon, DpStrategy::LbAsc);
    let cache = PlanCache::unbounded();
    let cold = simulate_iteration_timeline(&s, &cache);
    let warm = simulate_iteration_timeline(&s, &cache);
    assert_eq!(cold.total_s.to_bits(), warm.total_s.to_bits());
    assert_eq!(cold.fwd_bwd_s.to_bits(), warm.fwd_bwd_s.to_bits());
    assert_eq!(cold.bubble_s.to_bits(), warm.bubble_s.to_bits());
}

#[test]
fn dispatcher_routes_non_trivial_scenarios_to_the_timeline() {
    let cache = PlanCache::unbounded();
    let base = Scenario::new(Qwen3Size::S1_7B, 4, 2, 2, OptimKind::Muon, DpStrategy::LbAsc);
    for s in [
        base.clone().with_micro_batches(4),
        base.clone().with_schedule(PipelineSchedule::GPipe).with_micro_batches(2),
        Scenario::new(Qwen3Size::S1_7B, 8, 2, 1, OptimKind::Muon, DpStrategy::LbAsc)
            .with_straggler(1.5),
    ] {
        let via_dispatch = simulate_iteration_cached(&s, &cache);
        let direct = simulate_iteration_timeline(&s, &cache);
        assert_eq!(
            via_dispatch.total_s.to_bits(),
            direct.total_s.to_bits(),
            "dispatch and direct timeline disagree",
        );
        assert_eq!(via_dispatch.fwd_bwd_s.to_bits(), direct.fwd_bwd_s.to_bits());
        assert_eq!(via_dispatch.bubble_s.to_bits(), direct.bubble_s.to_bits());
    }
}

#[test]
fn pp_sweep_runs_end_to_end_with_deterministic_artifacts() {
    // `canzona sweep` with pp > 1 grids: two engine evaluations of the
    // same grid must produce byte-identical JSON artifacts.
    use canzona::sweep::{render_json, SweepEngine};
    let grid = SweepGrid {
        models: vec![Qwen3Size::S1_7B],
        dp: vec![4],
        tp: vec![2],
        pp: vec![1, 2, 4],
        micro_batches: vec![1, 4],
        schedules: vec![PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::LbAsc],
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0)],
        heteros: vec![canzona::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    };
    let a = SweepEngine::new(2);
    let (scens_a, res_a) = a.run_grid(&grid);
    let b = SweepEngine::new(4);
    let (scens_b, res_b) = b.run_grid(&grid);
    assert_eq!(
        render_json(&scens_a, &res_a).to_string(),
        render_json(&scens_b, &res_b).to_string(),
    );
    // pp rows carry a positive bubble; pp=1/m=1 rows a (near-)zero one.
    for (s, r) in scens_a.iter().zip(&res_a) {
        if s.pp > 1 {
            assert!(r.bubble_s > 0.0, "pp={} must have a bubble", s.pp);
        }
        assert!(r.total_s > 0.0);
    }
}
