//! Schedule invariants of the discrete-event timeline engine, checked
//! over randomized pipelines (random depth, micro-batch count, per-stage
//! durations, schedule choice):
//!
//! * no stream ever executes two tasks concurrently;
//! * every task starts at or after all of its dependencies complete;
//! * the makespan is >= the dependency-graph critical path and <= the
//!   serial sum of all durations;
//! * per-stage slot orders are complete and well-formed;
//! * for uniform stages the 1F1B (and GPipe) bubble fraction matches
//!   the analytic (pp-1)/(m+pp-1) within tolerance;
//! * **lean == recording**: over randomized task DAGs, a lean timeline
//!   produces bit-identical per-task ends, stream busy sums, serial sum
//!   and makespan to a recording one (the trace is pure observation);
//! * **flat == nested**: `drive_pipeline_flat` (production, reusable
//!   scratch + interned orders) emits the same task ids with the same
//!   bit-identical timings as the nested-table `drive_pipeline`
//!   reference.
//!
//! Invariant checks that read the trace build their timelines with
//! `Timeline::recording()`; the equivalence properties are exactly what
//! licenses the sweep hot path to run lean.

use canzona::sim::timeline::{
    build_pipeline, drive_pipeline_flat, schedule_order, schedule_order_iter, OrderCache,
    PipeScratch, PipeSlot, PipelineSchedule, StreamId, TaskId, TaskKind, Timeline,
};
use canzona::util::prop::check;
use canzona::util::rng::Rng;

const CASES: usize = 80;

struct Case {
    pp: usize,
    m: usize,
    sched: PipelineSchedule,
    fwd: Vec<f64>,
    bwd: Vec<f64>,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case(pp={}, m={}, {:?}, fwd={:?}, bwd={:?})",
            self.pp, self.m, self.sched, self.fwd, self.bwd
        )
    }
}

fn random_case(rng: &mut Rng) -> Case {
    let pp = 1 + rng.index(6);
    let m = 1 + rng.index(8);
    let sched = if rng.index(2) == 0 {
        PipelineSchedule::OneFOneB
    } else {
        PipelineSchedule::GPipe
    };
    let dur = |rng: &mut Rng| 0.1 + rng.next_f64() * 4.0;
    Case {
        pp,
        m,
        sched,
        fwd: (0..pp).map(|_| dur(rng)).collect(),
        bwd: (0..pp).map(|_| dur(rng)).collect(),
    }
}

fn build(case: &Case) -> Timeline {
    // Recording mode: these properties read the task trace.
    let mut tl = Timeline::recording();
    build_pipeline(&mut tl, case.sched, case.pp, case.m, &case.fwd, &case.bwd);
    tl
}

#[test]
fn prop_no_stream_runs_two_tasks_concurrently() {
    check("stream exclusivity", CASES, random_case, |c| {
        let tl = build(c);
        for s in 0..tl.n_streams() {
            let mut spans: Vec<(f64, f64)> = tl
                .tasks()
                .iter()
                .filter(|t| t.stream.0 as usize == s)
                .map(|t| (t.start, t.end))
                .collect();
            spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in spans.windows(2) {
                if w[1].0 < w[0].1 - 1e-12 {
                    return Err(format!(
                        "stream {s}: task starting {} overlaps one ending {}",
                        w[1].0, w[0].1
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tasks_start_after_their_dependencies() {
    check("dependency gating", CASES, random_case, |c| {
        let tl = build(c);
        for (i, t) in tl.tasks().iter().enumerate() {
            for &d in tl.deps_of(canzona::sim::timeline::TaskId(i as u32)) {
                let dep_end = tl.end(d);
                if t.start < dep_end - 1e-12 {
                    return Err(format!(
                        "task {i} starts {} before dependency ends {dep_end}",
                        t.start
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_within_critical_path_and_serial_sum() {
    check("makespan bounds", CASES, random_case, |c| {
        let tl = build(c);
        let ms = tl.makespan();
        let cp = tl.critical_path();
        let serial = tl.serial_sum();
        if ms < cp - 1e-9 {
            return Err(format!("makespan {ms} below critical path {cp}"));
        }
        if ms > serial + 1e-9 {
            return Err(format!("makespan {ms} above serial sum {serial}"));
        }
        // The busiest stage is also a lower bound.
        let busiest = (0..tl.n_streams())
            .map(|s| tl.stream_busy(canzona::sim::timeline::StreamId(s as u32)))
            .fold(0.0, f64::max);
        if ms < busiest - 1e-9 {
            return Err(format!("makespan {ms} below busiest stream {busiest}"));
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_orders_complete_and_causal() {
    check("slot orders", CASES, random_case, |c| {
        for stage in 0..c.pp {
            let order = schedule_order(c.sched, c.pp, stage, c.m);
            if order.len() != 2 * c.m {
                return Err(format!("stage {stage}: {} slots", order.len()));
            }
            for j in 0..c.m {
                let f = order.iter().position(|&s| s == PipeSlot::Fwd(j));
                let b = order.iter().position(|&s| s == PipeSlot::Bwd(j));
                match (f, b) {
                    (Some(f), Some(b)) if f < b => {}
                    _ => return Err(format!("stage {stage} mb {j}: bad F/B order")),
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_bubble_fraction_matches_analytic() {
    check(
        "1f1b bubble analytic",
        CASES,
        |rng| {
            let pp = 1 + rng.index(6);
            let m = 1 + rng.index(10);
            let f = 0.2 + rng.next_f64() * 3.0;
            let b = 0.2 + rng.next_f64() * 3.0;
            let sched = if rng.index(2) == 0 {
                PipelineSchedule::OneFOneB
            } else {
                PipelineSchedule::GPipe
            };
            (pp, m, f, b, sched)
        },
        |&(pp, m, f, b, sched)| {
            let mut tl = Timeline::new();
            build_pipeline(&mut tl, sched, pp, m, &vec![f; pp], &vec![b; pp]);
            let ms = tl.makespan();
            let expect = (m + pp - 1) as f64 * (f + b);
            if (ms - expect).abs() > 1e-9 * expect {
                return Err(format!("makespan {ms} != analytic {expect}"));
            }
            // Bubble fraction off the trace: 1 - busy/makespan on any
            // stage (uniform stages are all equally busy).
            let busy = tl.stream_busy(canzona::sim::timeline::StreamId(0));
            let frac = 1.0 - busy / ms;
            let analytic = (pp - 1) as f64 / (m + pp - 1) as f64;
            if (frac - analytic).abs() > 1e-9 {
                return Err(format!("bubble {frac} != analytic {analytic}"));
            }
            Ok(())
        },
    );
}

/// A randomized task DAG: streams, durations, and back-references to
/// earlier tasks as dependencies.
fn random_dag(rng: &mut Rng) -> (usize, Vec<(usize, f64, Vec<u32>)>) {
    let n_streams = 1 + rng.index(5);
    let n_tasks = 1 + rng.index(48);
    let mut tasks = Vec::with_capacity(n_tasks);
    for i in 0..n_tasks {
        let stream = rng.index(n_streams);
        let dur = rng.next_f64() * 3.0;
        let n_deps = rng.index(3.min(i + 1)); // 0 for the first task
        let deps: Vec<u32> = (0..n_deps).map(|_| rng.index(i) as u32).collect();
        tasks.push((stream, dur, deps));
    }
    (n_streams, tasks)
}

#[test]
fn prop_lean_and_recording_timelines_agree_on_random_dags() {
    check("lean == recording", CASES, random_dag, |case| {
        let (n_streams, tasks) = case;
        let run = |mut tl: Timeline| -> (Timeline, Vec<u64>) {
            let streams: Vec<StreamId> = (0..*n_streams).map(|_| tl.stream()).collect();
            let mut ids: Vec<TaskId> = Vec::with_capacity(tasks.len());
            let mut ends = Vec::with_capacity(tasks.len());
            for (stream, dur, deps) in tasks {
                let dep_ids: Vec<TaskId> = deps.iter().map(|&d| ids[d as usize]).collect();
                let id = tl.task(streams[*stream], TaskKind::Other, *dur, &dep_ids);
                ends.push(tl.end(id).to_bits());
                ids.push(id);
            }
            (tl, ends)
        };
        let (lean, lean_ends) = run(Timeline::new());
        let (rec, rec_ends) = run(Timeline::recording());
        if lean_ends != rec_ends {
            return Err("per-task end times diverged".into());
        }
        if lean.makespan().to_bits() != rec.makespan().to_bits() {
            return Err(format!(
                "makespan diverged: lean {} vs recording {}",
                lean.makespan(),
                rec.makespan()
            ));
        }
        if lean.serial_sum().to_bits() != rec.serial_sum().to_bits() {
            return Err("serial sum diverged".into());
        }
        if lean.n_tasks() != rec.n_tasks() {
            return Err("task counts diverged".into());
        }
        for s in 0..*n_streams {
            let sid = StreamId(s as u32);
            if lean.stream_busy(sid).to_bits() != rec.stream_busy(sid).to_bits() {
                return Err(format!("stream {s} busy diverged"));
            }
            if lean.stream_free(sid).to_bits() != rec.stream_free(sid).to_bits() {
                return Err(format!("stream {s} free diverged"));
            }
        }
        // The recording trace agrees with the lean accessors too.
        for (i, t) in rec.tasks().iter().enumerate() {
            if t.end.to_bits() != rec_ends[i] {
                return Err(format!("trace end of task {i} disagrees"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_flat_drive_shadow_equivalent_to_nested() {
    check("flat == nested drive", CASES, random_case, |c| {
        // Nested-table reference.
        let mut ref_tl = Timeline::new();
        let p = build_pipeline(&mut ref_tl, c.sched, c.pp, c.m, &c.fwd, &c.bwd);
        // Production driver: interned orders + flat scratch tables.
        let mut tl = Timeline::new();
        let compute: Vec<StreamId> = (0..c.pp).map(|_| tl.stream()).collect();
        let mut orders = OrderCache::new();
        let (slots, hit) = orders.get(c.sched, c.pp, c.m);
        if hit {
            return Err("fresh order cache reported a hit".into());
        }
        let mut sc = PipeScratch::new();
        drive_pipeline_flat(&mut tl, slots, c.pp, c.m, &mut sc, |tl, i, slot, deps| {
            match slot {
                PipeSlot::Fwd(_) => tl.task(compute[i], TaskKind::Forward, c.fwd[i], deps),
                PipeSlot::Bwd(_) => tl.task(compute[i], TaskKind::Backward, c.bwd[i], deps),
            }
        });
        if tl.n_tasks() != ref_tl.n_tasks() {
            return Err("task counts diverged".into());
        }
        if tl.makespan().to_bits() != ref_tl.makespan().to_bits() {
            return Err(format!(
                "makespan diverged: flat {} vs nested {}",
                tl.makespan(),
                ref_tl.makespan()
            ));
        }
        for i in 0..c.pp {
            if tl.stream_busy(compute[i]).to_bits()
                != ref_tl.stream_busy(p.compute[i]).to_bits()
            {
                return Err(format!("stage {i} busy diverged"));
            }
            for j in 0..c.m {
                if sc.fwd_id(i, j) != p.fwd[i][j] || sc.bwd_id(i, j) != p.bwd[i][j] {
                    return Err(format!("completion ids diverged at stage {i} mb {j}"));
                }
                if tl.end(sc.fwd_id(i, j)).to_bits() != ref_tl.end(p.fwd[i][j]).to_bits() {
                    return Err(format!("F({i},{j}) end diverged"));
                }
                if tl.end(sc.bwd_id(i, j)).to_bits() != ref_tl.end(p.bwd[i][j]).to_bits() {
                    return Err(format!("B({i},{j}) end diverged"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_order_iter_matches_collected_order() {
    check(
        "order iterator == Vec expansion",
        CASES,
        |rng| {
            let pp = 1 + rng.index(6);
            let stage = rng.index(pp);
            let m = 1 + rng.index(10);
            let sched = if rng.index(2) == 0 {
                PipelineSchedule::OneFOneB
            } else {
                PipelineSchedule::GPipe
            };
            (sched, pp, stage, m)
        },
        |&(sched, pp, stage, m)| {
            // Straightforward push-loop reference (the pre-iterator
            // expansion): warmup forwards, steady F/B alternation,
            // cooldown backwards — all-forward warmup for GPipe.
            let mut expect = Vec::with_capacity(2 * m);
            match sched {
                PipelineSchedule::GPipe => {
                    expect.extend((0..m).map(PipeSlot::Fwd));
                    expect.extend((0..m).map(PipeSlot::Bwd));
                }
                PipelineSchedule::OneFOneB => {
                    let w = (pp - 1 - stage).min(m);
                    for j in 0..w {
                        expect.push(PipeSlot::Fwd(j));
                    }
                    for k in 0..(m - w) {
                        expect.push(PipeSlot::Fwd(w + k));
                        expect.push(PipeSlot::Bwd(k));
                    }
                    for k in (m - w)..m {
                        expect.push(PipeSlot::Bwd(k));
                    }
                }
            }
            let via_iter: Vec<PipeSlot> = schedule_order_iter(sched, pp, stage, m).collect();
            if via_iter != expect {
                return Err(format!("{sched:?} pp{pp} s{stage} m{m}: orders diverged"));
            }
            if schedule_order(sched, pp, stage, m) != expect {
                return Err("Vec form diverged from reference".into());
            }
            if schedule_order_iter(sched, pp, stage, m).len() != 2 * m {
                return Err("iterator length wrong".into());
            }
            Ok(())
        },
    );
}

#[test]
fn scenario_timeline_respects_bounds_too() {
    // End-to-end: the full-iteration timeline's Breakdown obeys the same
    // bounds — bubble below the span, total at least the span, and the
    // pp=4 bubble fraction within a loose band of the analytic (the
    // embed/head stages skew uniformity).
    use canzona::cost::optim::OptimKind;
    use canzona::model::qwen3::Qwen3Size;
    use canzona::partition::DpStrategy;
    use canzona::sim::{simulate_iteration, Scenario};
    for m in [1usize, 4, 16] {
        let s = Scenario::new(Qwen3Size::S1_7B, 2, 1, 4, OptimKind::Muon, DpStrategy::LbAsc)
            .with_micro_batches(m);
        let b = simulate_iteration(&s);
        assert!(b.bubble_s >= 0.0 && b.bubble_s < b.fwd_bwd_s, "m={m}: {b:?}");
        assert!(b.total_s >= b.fwd_bwd_s);
        let analytic = 3.0 / (m as f64 + 3.0);
        let frac = b.bubble_s / b.fwd_bwd_s;
        assert!(
            (frac - analytic).abs() < 0.35,
            "m={m}: bubble fraction {frac} far from analytic {analytic}",
        );
    }
}
