//! Schedule invariants of the discrete-event timeline engine, checked
//! over randomized pipelines (random depth, micro-batch count, per-stage
//! durations, schedule choice):
//!
//! * no stream ever executes two tasks concurrently;
//! * every task starts at or after all of its dependencies complete;
//! * the makespan is >= the dependency-graph critical path and <= the
//!   serial sum of all durations;
//! * per-stage slot orders are complete and well-formed;
//! * for uniform stages the 1F1B (and GPipe) bubble fraction matches
//!   the analytic (pp-1)/(m+pp-1) within tolerance.

use canzona::sim::timeline::{
    build_pipeline, schedule_order, PipeSlot, PipelineSchedule, Timeline,
};
use canzona::util::prop::check;
use canzona::util::rng::Rng;

const CASES: usize = 80;

struct Case {
    pp: usize,
    m: usize,
    sched: PipelineSchedule,
    fwd: Vec<f64>,
    bwd: Vec<f64>,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "Case(pp={}, m={}, {:?}, fwd={:?}, bwd={:?})",
            self.pp, self.m, self.sched, self.fwd, self.bwd
        )
    }
}

fn random_case(rng: &mut Rng) -> Case {
    let pp = 1 + rng.index(6);
    let m = 1 + rng.index(8);
    let sched = if rng.index(2) == 0 {
        PipelineSchedule::OneFOneB
    } else {
        PipelineSchedule::GPipe
    };
    let dur = |rng: &mut Rng| 0.1 + rng.next_f64() * 4.0;
    Case {
        pp,
        m,
        sched,
        fwd: (0..pp).map(|_| dur(rng)).collect(),
        bwd: (0..pp).map(|_| dur(rng)).collect(),
    }
}

fn build(case: &Case) -> Timeline {
    let mut tl = Timeline::new();
    build_pipeline(&mut tl, case.sched, case.pp, case.m, &case.fwd, &case.bwd);
    tl
}

#[test]
fn prop_no_stream_runs_two_tasks_concurrently() {
    check("stream exclusivity", CASES, random_case, |c| {
        let tl = build(c);
        for s in 0..tl.n_streams() {
            let mut spans: Vec<(f64, f64)> = tl
                .tasks()
                .iter()
                .filter(|t| t.stream.0 as usize == s)
                .map(|t| (t.start, t.end))
                .collect();
            spans.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            for w in spans.windows(2) {
                if w[1].0 < w[0].1 - 1e-12 {
                    return Err(format!(
                        "stream {s}: task starting {} overlaps one ending {}",
                        w[1].0, w[0].1
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_tasks_start_after_their_dependencies() {
    check("dependency gating", CASES, random_case, |c| {
        let tl = build(c);
        for (i, t) in tl.tasks().iter().enumerate() {
            for &d in tl.deps_of(canzona::sim::timeline::TaskId(i as u32)) {
                let dep_end = tl.end(d);
                if t.start < dep_end - 1e-12 {
                    return Err(format!(
                        "task {i} starts {} before dependency ends {dep_end}",
                        t.start
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_makespan_within_critical_path_and_serial_sum() {
    check("makespan bounds", CASES, random_case, |c| {
        let tl = build(c);
        let ms = tl.makespan();
        let cp = tl.critical_path();
        let serial = tl.serial_sum();
        if ms < cp - 1e-9 {
            return Err(format!("makespan {ms} below critical path {cp}"));
        }
        if ms > serial + 1e-9 {
            return Err(format!("makespan {ms} above serial sum {serial}"));
        }
        // The busiest stage is also a lower bound.
        let busiest = (0..tl.n_streams())
            .map(|s| tl.stream_busy(canzona::sim::timeline::StreamId(s as u32)))
            .fold(0.0, f64::max);
        if ms < busiest - 1e-9 {
            return Err(format!("makespan {ms} below busiest stream {busiest}"));
        }
        Ok(())
    });
}

#[test]
fn prop_schedule_orders_complete_and_causal() {
    check("slot orders", CASES, random_case, |c| {
        for stage in 0..c.pp {
            let order = schedule_order(c.sched, c.pp, stage, c.m);
            if order.len() != 2 * c.m {
                return Err(format!("stage {stage}: {} slots", order.len()));
            }
            for j in 0..c.m {
                let f = order.iter().position(|&s| s == PipeSlot::Fwd(j));
                let b = order.iter().position(|&s| s == PipeSlot::Bwd(j));
                match (f, b) {
                    (Some(f), Some(b)) if f < b => {}
                    _ => return Err(format!("stage {stage} mb {j}: bad F/B order")),
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_uniform_bubble_fraction_matches_analytic() {
    check(
        "1f1b bubble analytic",
        CASES,
        |rng| {
            let pp = 1 + rng.index(6);
            let m = 1 + rng.index(10);
            let f = 0.2 + rng.next_f64() * 3.0;
            let b = 0.2 + rng.next_f64() * 3.0;
            let sched = if rng.index(2) == 0 {
                PipelineSchedule::OneFOneB
            } else {
                PipelineSchedule::GPipe
            };
            (pp, m, f, b, sched)
        },
        |&(pp, m, f, b, sched)| {
            let mut tl = Timeline::new();
            build_pipeline(&mut tl, sched, pp, m, &vec![f; pp], &vec![b; pp]);
            let ms = tl.makespan();
            let expect = (m + pp - 1) as f64 * (f + b);
            if (ms - expect).abs() > 1e-9 * expect {
                return Err(format!("makespan {ms} != analytic {expect}"));
            }
            // Bubble fraction off the trace: 1 - busy/makespan on any
            // stage (uniform stages are all equally busy).
            let busy = tl.stream_busy(canzona::sim::timeline::StreamId(0));
            let frac = 1.0 - busy / ms;
            let analytic = (pp - 1) as f64 / (m + pp - 1) as f64;
            if (frac - analytic).abs() > 1e-9 {
                return Err(format!("bubble {frac} != analytic {analytic}"));
            }
            Ok(())
        },
    );
}

#[test]
fn scenario_timeline_respects_bounds_too() {
    // End-to-end: the full-iteration timeline's Breakdown obeys the same
    // bounds — bubble below the span, total at least the span, and the
    // pp=4 bubble fraction within a loose band of the analytic (the
    // embed/head stages skew uniformity).
    use canzona::cost::optim::OptimKind;
    use canzona::model::qwen3::Qwen3Size;
    use canzona::partition::DpStrategy;
    use canzona::sim::{simulate_iteration, Scenario};
    for m in [1usize, 4, 16] {
        let s = Scenario::new(Qwen3Size::S1_7B, 2, 1, 4, OptimKind::Muon, DpStrategy::LbAsc)
            .with_micro_batches(m);
        let b = simulate_iteration(&s);
        assert!(b.bubble_s >= 0.0 && b.bubble_s < b.fwd_bwd_s, "m={m}: {b:?}");
        assert!(b.total_s >= b.fwd_bwd_s);
        let analytic = 3.0 / (m as f64 + 3.0);
        let frac = b.bubble_s / b.fwd_bwd_s;
        assert!(
            (frac - analytic).abs() < 0.35,
            "m={m}: bubble fraction {frac} far from analytic {analytic}",
        );
    }
}
