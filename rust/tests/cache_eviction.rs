//! Property tests for the plan cache's LRU byte budget:
//!
//! * the resident byte total never exceeds the budget, under arbitrary
//!   interleavings of inserts and re-touches;
//! * eviction is LRU-first (the least-recently-touched key goes first);
//! * an evicted-then-recomputed plan is byte-identical to the original
//!   (the solvers are deterministic, so eviction is semantically
//!   invisible) — checked for synthetic plans and a real
//!   `alpha_balanced` solve;
//! * a byte-bounded engine sweep produces byte-identical artifacts to an
//!   unbounded one while actually evicting.

use canzona::buffer::FlatBuffer;
use canzona::cost::optim::{CostMetric, OptimKind};
use canzona::model::qwen3::Qwen3Size;
use canzona::model::shapes::{Param, ParamKind, TensorShape};
use canzona::partition::{alpha_balanced, Atomicity, DpPlan, DpStrategy};
use canzona::sweep::{render_table, DpKey, PlanCache, SweepEngine, SweepGrid};
use canzona::util::prop::check;
use canzona::util::rng::Rng;

fn key(stage: usize) -> DpKey {
    DpKey {
        model: Qwen3Size::S1_7B,
        stage,
        pp: 1,
        dp: 8,
        tp: 2,
        strategy: DpStrategy::LbAsc,
        optim: None,
        metric: CostMetric::Numel,
        alpha_bits: 1.0f64.to_bits(),
        bucket_elems: 40_000_000,
    }
}

/// Deterministic synthetic plan: content (and size) derived from `i`.
fn plan(i: usize) -> DpPlan {
    let ranks = 2 + i % 6;
    DpPlan {
        ranks,
        cuts: vec![(0..=ranks).map(|r| r * (10 + i)).collect()],
        atomicity: Atomicity::None,
    }
}

#[test]
fn prop_budget_never_exceeded_and_plans_recompute_identically() {
    check(
        "cache byte budget",
        40,
        |rng: &mut Rng| {
            let n_keys = 2 + rng.index(12);
            let ops: Vec<usize> = (0..40).map(|_| rng.index(n_keys)).collect();
            // Budget sized between ~1 and ~4 typical entries so eviction
            // is constantly exercised.
            let budget = 300 + rng.index(1200);
            (ops, budget)
        },
        |(ops, budget)| {
            let cache = PlanCache::with_budget(*budget);
            let mut originals: Vec<Option<String>> = vec![None; 16];
            for &i in ops {
                let got = cache.dp_plan(&key(i), || plan(i));
                let bytes = format!("{got:?}");
                match &originals[i] {
                    // Evicted-then-recomputed (or cached) plans must be
                    // byte-identical to the first solve.
                    Some(first) => {
                        if first != &bytes {
                            return Err(format!("plan {i} drifted after eviction"));
                        }
                    }
                    None => originals[i] = Some(bytes),
                }
                let s = cache.stats();
                if s.budget_bytes != 0 && s.resident_bytes > s.budget_bytes {
                    return Err(format!("budget exceeded: {s:?}"));
                }
                if s.budget_bytes != 0 && s.peak_bytes > s.budget_bytes {
                    return Err(format!("peak exceeded budget: {s:?}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn eviction_is_lru_first() {
    // Weigh one entry, then give the cache room for exactly three.
    let probe = PlanCache::unbounded();
    probe.dp_plan(&key(0), || plan(0));
    let per_entry = probe.stats().resident_bytes as usize;
    // plan(i) sizes vary slightly with ranks; use the largest variant.
    let cache = PlanCache::with_budget(3 * (per_entry + 64));

    cache.dp_plan(&key(0), || plan(0));
    cache.dp_plan(&key(1), || plan(1));
    cache.dp_plan(&key(2), || plan(2));
    // Touch 0 and 2; key 1 becomes the LRU.
    cache.dp_plan(&key(0), || panic!("hit expected"));
    cache.dp_plan(&key(2), || panic!("hit expected"));
    // Insert until something is evicted; LRU order must be 1, then 0.
    cache.dp_plan(&key(3), || plan(3));
    let stats = cache.stats();
    assert!(stats.evictions >= 1, "{stats:?}");
    assert!(!cache.contains_dp(&key(1)), "LRU key survived");
    assert!(cache.contains_dp(&key(3)), "fresh key missing");
    assert!(
        cache.contains_dp(&key(0)) || cache.contains_dp(&key(2)),
        "recently-touched keys both gone",
    );
    assert!(stats.resident_bytes <= stats.budget_bytes, "{stats:?}");
}

#[test]
fn budget_holds_with_and_without_the_l1_read_path() {
    // The per-thread L1 (enabled by default — every other test in this
    // file already runs through it) must not change byte accounting:
    // identical op mixes against an L1-enabled and a mutex-only cache
    // stay within budget with identical resident totals, and heavy
    // L1-hit streaks between inserts never delay an eviction.
    let probe = PlanCache::unbounded();
    probe.dp_plan(&key(0), || plan(0));
    let per_entry = probe.stats().resident_bytes as usize;
    let budget = 3 * (per_entry + 64);
    let l1 = PlanCache::with_options(budget, true);
    let mutex_only = PlanCache::with_options(budget, false);
    for round in 0..6 {
        for i in 0..6 {
            l1.dp_plan(&key(i), || plan(i));
            mutex_only.dp_plan(&key(i), || plan(i));
            // A hit streak on the freshest key (pure L1 on one side).
            for _ in 0..10 {
                l1.dp_plan(&key(i), || panic!("hit expected"));
                mutex_only.dp_plan(&key(i), || panic!("hit expected"));
            }
            let a = l1.stats();
            let b = mutex_only.stats();
            assert!(a.resident_bytes <= a.budget_bytes, "round {round}: {a:?}");
            assert_eq!(
                (a.resident_bytes, a.evictions, a.solves),
                (b.resident_bytes, b.evictions, b.solves),
                "round {round} key {i}: L1 changed eviction accounting",
            );
        }
    }
    assert!(l1.stats().evictions > 0, "the mix must exercise eviction");
    assert!(l1.stats().l1_hits > 0, "the mix must exercise the L1");
}

#[test]
fn real_solver_recomputes_bit_identical_after_eviction() {
    let params: Vec<Param> = (0..12)
        .map(|i| {
            let kind = if i % 3 == 0 { ParamKind::Vector } else { ParamKind::Matrix };
            let shape = match kind {
                ParamKind::Vector => TensorShape::vector(512 + i),
                _ => TensorShape::matrix(64, 32 + i),
            };
            Param::new(&format!("p{i}"), shape, kind, Some(i / 4))
        })
        .collect();
    let fb = FlatBuffer::build(&params, 10_000);
    let solve = || alpha_balanced(&fb, 4, 1.0, true, |p| p.numel() as f64);

    let probe = PlanCache::unbounded();
    probe.dp_plan(&key(0), solve);
    let per_entry = probe.stats().resident_bytes as usize;

    let cache = PlanCache::with_budget(per_entry + 64);
    let first = cache.dp_plan(&key(0), solve);
    let first_cuts = first.cuts.clone();
    // A second, different key evicts the first (budget fits ~one).
    cache.dp_plan(&key(1), solve);
    assert!(cache.stats().evictions >= 1);
    assert!(!cache.contains_dp(&key(0)), "expected key 0 evicted");
    // Recompute: byte-identical cuts.
    let again = cache.dp_plan(&key(0), solve);
    assert_eq!(first_cuts, again.cuts, "evicted plan did not recompute identically");
}

#[test]
fn bounded_family_sweep_evicts_but_matches_unbounded_results() {
    // A DP=128 slice of the family sweep under a deliberately tiny
    // budget: the cache must evict (counters prove it), stay within
    // budget, and render byte-identical tables to an unbounded engine —
    // eviction is semantically invisible.
    let grid = SweepGrid {
        models: vec![Qwen3Size::S1_7B],
        dp: vec![128],
        tp: vec![2, 4],
        pp: vec![1],
        micro_batches: vec![1],
        schedules: vec![canzona::sim::PipelineSchedule::OneFOneB],
        stragglers: vec![1.0],
        optims: vec![OptimKind::Muon],
        strategies: vec![DpStrategy::LbAsc],
        alphas: vec![1.0],
        c_max_mb: vec![Some(256.0)],
        heteros: vec![canzona::sim::HeteroSpec::None],
        fail_ranks: vec![None],
        mttfs: vec![None],
        ckpt_intervals: vec![1],
        metric: CostMetric::Numel,
        fault_seed: 0,
    };
    let unbounded = SweepEngine::with_budget(2, 0);
    let (scens_u, res_u) = unbounded.run_grid(&grid);
    assert_eq!(unbounded.cache_stats().evictions, 0);

    let bounded = SweepEngine::with_budget(2, 64 * 1024);
    let (scens_b, res_b) = bounded.run_grid(&grid);
    // Warm second pass under pressure: still correct.
    let res_b2 = bounded.eval(&scens_b);
    let stats = bounded.cache_stats();
    assert!(stats.evictions > 0, "64 KB must force evictions: {stats:?}");
    assert!(
        stats.resident_bytes <= stats.budget_bytes,
        "budget violated: {stats:?}",
    );
    assert_eq!(
        render_table(&scens_u, &res_u).render(),
        render_table(&scens_b, &res_b).render(),
        "bounded cache changed sweep results",
    );
    assert_eq!(
        render_table(&scens_b, &res_b).render(),
        render_table(&scens_b, &res_b2).render(),
        "eviction-pressure rerun changed results",
    );
}
